"""Execution context: *where* a plan will run, as optimizer input.

Cobra's cost model (Sec. VI) prices a program as if it executes once, on a
cold client. The serving runtime invalidates both assumptions: ``run_batch``
shares one client environment across a whole batch (a parameterless query
site is fetched from the server once per batch — the paper's batching
transformation applied at the serving layer), and the feedback loop observes
true while-loop iteration counts where the catalog only has a default. The
:class:`ExecutionContext` packages exactly those runtime parameters —

  * ``batch_size``   — how many invocations share one client environment;
  * ``hw``           — an optional hardware-profile override (the step-program
    planner's HW table; program plans ignore it but key on it);
  * ``stats``        — a :class:`StatsProfile` of observed per-site iteration
    counts and wall-clock feedback published by the
    :class:`~repro.runtime.feedback.FeedbackController`

— and threads them from ``CobraSession.compile()`` / ``ServingRuntime``
into :class:`~repro.core.cost.CostModel`, so the memo search can pick a
*different* winning alternative for one-shot vs high-traffic execution of
the same program. Context identity (:meth:`ExecutionContext.fingerprint`)
is part of every plan-cache/plan-store key, restricted to the iteration
sites a program actually contains so an unrelated site's observation leaves
other programs' plans hot (mirroring per-table stats versions).

Iteration **sites** are stable content keys: :func:`while_site_key` hashes a
while guard's expression key, :func:`loop_site_key` a cursor loop's
(var, source) pair — the same key the interpreter records observations
under and the cost model looks estimates up by.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Mapping, Optional, Sequence, Tuple

__all__ = ["ExecutionContext", "StatsProfile", "ONE_SHOT",
           "while_site_key", "loop_site_key", "query_site_key",
           "param_group_key", "param_prov_key"]


def _site_hash(key: Tuple) -> str:
    return hashlib.sha256(repr(key).encode()).hexdigest()[:12]


def while_site_key(pred) -> str:
    """Stable site id of a guarded (while) loop, from its guard expression."""
    return "while:" + _site_hash(pred.key())


def loop_site_key(var: str, source) -> str:
    """Stable site id of a cursor loop over a non-query (collection) source —
    the loops whose iteration count table statistics cannot estimate."""
    return "loop:" + _site_hash((var, source.key()))


def query_site_key(query) -> str:
    """Stable site id of one exact query tree — the key the serving-level
    :class:`~repro.runtime.sitecache.SiteCache` tracks per-site binding
    diversity under (telemetry granularity)."""
    return "qsite:" + _site_hash(query.key())


def param_group_key(tables) -> str:
    """Stable id of a PARAMETERIZED-site group: all parameterized query
    sites over one base-table set. Binding-diversity statistics publish at
    this granularity because rewrites change the exact query tree (T5 turns
    a σ into an aggregate over it) while the table set survives every
    rewrite — so a diversity observed under the running plan prices the
    *other* alternatives of the same site too."""
    return "qdiv:" + _site_hash(tuple(sorted(tables)))


def param_prov_key(tables, param_cols) -> str:
    """Stable PROVENANCE id of a parameterized query site: the site's
    base-table set *plus the columns its parameters are compared
    against*. Finer than :func:`param_group_key` — two differently-diverse
    sites over one table filter different columns (W_E's
    ``t_role_id = :rid`` vs SCAN's ``t_state = :k``), so their diversity
    observations publish (and price) separately — yet still coarse enough
    to survive every rewrite: T2/T5-style transformations rebuild the
    query tree (even renaming the parameter to a synthetic ``:k``) but
    preserve the tables scanned and the predicate column, which becomes
    the rewritten form's lookup key column. The cost model consults the
    provenance key first and falls back to the table-group key."""
    return "qprov:" + _site_hash((tuple(sorted(tables)),
                                  tuple(sorted(param_cols))))


@dataclasses.dataclass(frozen=True)
class StatsProfile:
    """Observed runtime statistics, published by the feedback controller.

    ``iters`` maps iteration sites (``while:…`` / ``loop:…`` keys) to the
    observed iteration count the cost model should use instead of the
    catalog default (``while_iters_default`` / ``loop_iters_default``).
    ``bindings`` maps parameterized-site groups (``qdiv:…`` keys, see
    :func:`param_group_key`) to the observed distinct-binding fraction in
    [0, 1] — the serving site cache's measurement of how often a
    parameterized site's bindings actually repeat across a batch, which
    the cost model uses to amortize parameterized fetches instead of the
    0/1 binding-free rule. ``site_wall_s`` maps query sites (by SQL text)
    to observed mean wall-clock seconds — the default
    :class:`~repro.core.cost.CostModel` does not consume it (wall-clock
    drift feeds the stats-version invalidation path instead), but custom
    cost models may calibrate against it. ``qerrors`` maps query sites
    (by SQL text) to their latest observed q-error — max(est/act, act/est)
    of the site's cardinality estimate, tracked by the feedback
    controller's :class:`~repro.stats.qerror.QErrorTracker`; it is the
    signal behind targeted re-analyzes and the per-site column
    ``explain()``/``triage()`` surface. ``iters`` and ``bindings``
    participate in plan identity; ``site_wall_s`` and ``qerrors`` do not
    (q-error moves with every observation — keying plans on it would
    thrash the caches re-analyze exists to protect).
    """

    iters: Tuple[Tuple[str, float], ...] = ()
    site_wall_s: Tuple[Tuple[str, float], ...] = ()
    bindings: Tuple[Tuple[str, float], ...] = ()
    qerrors: Tuple[Tuple[str, float], ...] = ()

    @classmethod
    def of(cls, iters: Optional[Mapping[str, float]] = None,
           site_wall_s: Optional[Mapping[str, float]] = None,
           bindings: Optional[Mapping[str, float]] = None,
           qerrors: Optional[Mapping[str, float]] = None) -> "StatsProfile":
        return cls(
            iters=tuple(sorted((k, float(v)) for k, v in (iters or {}).items())),
            site_wall_s=tuple(sorted((k, float(v))
                              for k, v in (site_wall_s or {}).items())),
            bindings=tuple(sorted((k, float(v))
                           for k, v in (bindings or {}).items())),
            qerrors=tuple(sorted((k, float(v))
                          for k, v in (qerrors or {}).items())))

    def iters_for(self, site: str) -> Optional[float]:
        for k, v in self.iters:
            if k == site:
                return v
        return None

    def binding_for(self, site: str) -> Optional[float]:
        for k, v in self.bindings:
            if k == site:
                return v
        return None

    def wall_for(self, sql: str) -> Optional[float]:
        for k, v in self.site_wall_s:
            if k == sql:
                return v
        return None

    def qerror_for(self, sql: str) -> Optional[float]:
        for k, v in self.qerrors:
            if k == sql:
                return v
        return None

    def as_dicts(self) -> Tuple[Dict[str, float], Dict[str, float]]:
        return dict(self.iters), dict(self.site_wall_s)


_EMPTY_STATS = StatsProfile()


@dataclasses.dataclass(frozen=True)
class ExecutionContext:
    """The runtime parameters a plan is optimized *for*."""

    batch_size: int = 1
    hw: Tuple[Tuple[str, float], ...] = ()   # optional HW-profile override
    stats: StatsProfile = _EMPTY_STATS

    def __post_init__(self):
        if self.batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if isinstance(self.hw, dict):
            object.__setattr__(self, "hw", tuple(sorted(self.hw.items())))

    @classmethod
    def serving(cls, batch_size: int,
                stats: Optional[StatsProfile] = None) -> "ExecutionContext":
        return cls(batch_size=batch_size, stats=stats or _EMPTY_STATS)

    def with_stats(self, stats: StatsProfile) -> "ExecutionContext":
        return dataclasses.replace(self, stats=stats)

    # -------------------------------------------------------------- identity
    def fingerprint(self, sites: Optional[Sequence[str]] = None) -> Tuple:
        """Plan-key component. ``sites`` restricts the stats part to the
        iteration sites and parameterized-site groups one program contains,
        so observations at sites the program doesn't have never invalidate
        its plans (the per-table stats-version idea, applied to iteration
        and binding-diversity statistics)."""
        if sites is None:
            rel = self.stats.iters
            rel_b = self.stats.bindings
        else:
            want = set(sites)
            rel = tuple(kv for kv in self.stats.iters if kv[0] in want)
            rel_b = tuple(kv for kv in self.stats.bindings if kv[0] in want)
        return ("ctx", self.batch_size, self.hw, rel, rel_b)

    def describe(self) -> str:
        n = len(self.stats.iters)
        b = len(self.stats.bindings)
        return (f"batch={self.batch_size}"
                + (f", {n} observed iteration site(s)" if n else "")
                + (f", {b} binding-diversity site(s)" if b else ""))


ONE_SHOT = ExecutionContext()
