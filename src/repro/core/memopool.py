"""Cross-program memo-group sharing (`MemoPool`).

Saturation over one cursor loop is **context-independent**: the
alternatives the rules derive for a ``loop`` AND-node depend only on the
loop's region subtree, the emptiness facts at its entry, the database
schema/statistics, and the rule set — never on the surrounding program or
the execution context the plan is later costed for. A session-scoped pool
therefore keys each loop's saturated group structure by

    (canonical subtree key, entry-empty vars, stats epoch, rule set)

and replays it into the next memo that builds the same loop — the other
programs of a serving tier, and every context-driven recompile of the same
program, skip rule saturation for shared loops entirely. Replayed nodes
are marked *prefired* so ``expand`` never visits them (their alternatives
are already saturated), and provenance/rule-hit accounting is restored for
every distinct replayed alternative. The replayed MEMO is bit-identical to
a cold compile's (same fingerprint, same winning plan); only duplicate
ATTEMPTS — cold firings that re-derived an already-present variant — are
not replayed, so attempt counters can read lower than a cold compile's.

The stats epoch in the key covers exactly the tables the loop touches, so
an ``analyze()`` on an unrelated table leaves the entry hot; the rule-set
fingerprint covers name, operator, phase, and function identity, so a
session that swaps rule sets never replays stale structure. Harvesting is
conservative: any loop whose group structure deviates from the canonical
``assemble`` + slot-group shape (e.g. through an unexpected cross-loop
group merge) is simply not pooled — correctness never depends on a hit.

Hit/miss counters surface in ``session.telemetry`` and
``metrics_snapshot()`` (``memo_pool_hits`` / ``memo_pool_misses``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .dag import AndNode, Memo

__all__ = ["MemoPool"]

_SLOT_OPS = ("slot-project", "slot-query", "slot-query-rows")


@dataclasses.dataclass(frozen=True)
class _SlotRec:
    """One harvested slot alternative: operator + payload + how it was
    derived (rule name and the index of its source member within the same
    var group; -1 = derived from the loop node itself, i.e. by toFIR)."""

    op: str
    payload: object
    rule: Optional[str]
    src: int


@dataclasses.dataclass(frozen=True)
class _PoolEntry:
    assemble_payload: object                       # ("assemble", acc_names)
    assemble_rule: Optional[str]                   # provenance of the assemble
    var_groups: Tuple[Tuple[_SlotRec, ...], ...]   # per child group, in order


def _region_tables(region) -> Tuple[str, ...]:
    from ..api.cache import program_tables

    class _Shim:
        body = region
    return program_tables(_Shim)


class MemoPool:
    """Session-scoped cache of saturated memo groups, keyed per loop."""

    def __init__(self, metrics=None):
        self._entries: Dict[Tuple, _PoolEntry] = {}
        self.hits = 0
        self.misses = 0
        self.metrics = metrics          # obs.MetricsRegistry (optional)

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------------------- keying
    @staticmethod
    def rules_fingerprint(rules) -> Tuple:
        """Identity of a rule list for pool keying: name, match operator,
        phase, and the function object itself (a user editing a rule
        mid-session produces a new function, hence a new fingerprint)."""
        return tuple((r.name, r.op, getattr(r, "phase", "explore"), id(r.fn))
                     for r in rules)

    def _key(self, region, empties, db, rules_fp) -> Tuple:
        return (region.key(), tuple(sorted(empties)),
                db.stats_token(_region_tables(region)), rules_fp)

    def _count(self, counter: str) -> None:
        if self.metrics is not None:
            self.metrics.inc(f"memo_pool_{counter}")

    # -------------------------------------------------------------- seed
    def seed(self, memo: Memo, ctx, rules) -> Tuple[int, Set[int]]:
        """Replay pooled group structure into a freshly-built memo.

        For every ``loop`` AND-node whose key hits the pool, the harvested
        var groups and the ``assemble`` alternative are re-inserted (with
        provenance and rule-hit accounting restored) and all restored
        nodes — plus the loop node itself — are marked prefired.

        Returns ``(alternatives_replayed, prefired_and_ids)``."""
        prefired: Set[int] = set()
        replayed = 0
        if not ctx.loop_regions:
            return 0, prefired
        rules_fp = self.rules_fingerprint(rules)
        for and_id, region in list(ctx.loop_regions.items()):
            key = self._key(region, ctx.empty_at_loop.get(and_id, frozenset()),
                            ctx.db, rules_fp)
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                self._count("misses")
                continue
            replayed += self._replay(memo, and_id, entry, prefired)
            self.hits += 1
            self._count("hits")
        return replayed, prefired

    def _replay(self, memo: Memo, loop_id: int, entry: _PoolEntry,
                prefired: Set[int]) -> int:
        # rule-hit restoration mirrors cold-compile accounting exactly:
        # toFIR fires ONCE per loop (however many slots it creates), every
        # slot-variant rule fires once per variant it derived
        replayed = 0
        var_gids: List[int] = []
        for recs in entry.var_groups:
            g: Optional[int] = None
            ids: List[int] = []
            for rec in recs:
                g2, nid = memo.insert(AndNode(rec.op, (), rec.payload),
                                      group=g)
                g = g2
                ids.append(nid)
                prefired.add(nid)
                if rec.rule is not None:
                    src = loop_id if rec.src < 0 else ids[rec.src]
                    memo.provenance.setdefault(nid, (rec.rule, src))
                    if rec.src >= 0:
                        memo.rule_hits[rec.rule] = \
                            memo.rule_hits.get(rec.rule, 0) + 1
                        replayed += 1
            var_gids.append(g)
        _, aid = memo.insert(
            AndNode("assemble", tuple(var_gids), entry.assemble_payload),
            group=memo.owner(loop_id))
        prefired.add(aid)
        prefired.add(loop_id)
        if entry.assemble_rule is not None:
            memo.provenance.setdefault(aid, (entry.assemble_rule, loop_id))
            memo.rule_hits[entry.assemble_rule] = \
                memo.rule_hits.get(entry.assemble_rule, 0) + 1
        replayed += 1
        return replayed

    # ------------------------------------------------------------ harvest
    def harvest(self, memo: Memo, ctx, rules, prefired: Set[int]) -> int:
        """Record the saturated group structure of every un-pooled loop.

        Must only be called on a FULLY saturated memo (never after a
        budget-exhausted stop — a partial harvest would poison later
        compiles). Returns the number of entries added."""
        added = 0
        rules_fp = self.rules_fingerprint(rules)
        for and_id, region in list(ctx.loop_regions.items()):
            if and_id in prefired:
                continue        # replayed from the pool this compile
            entry = self._harvest_loop(memo, and_id)
            if entry is None:
                continue
            key = self._key(region, ctx.empty_at_loop.get(and_id, frozenset()),
                            ctx.db, rules_fp)
            if key not in self._entries:
                self._entries[key] = entry
                added += 1
        if self.metrics is not None and added:
            self.metrics.gauge("memo_pool_entries", len(self._entries))
        return added

    def _harvest_loop(self, memo: Memo, loop_id: int) -> Optional[_PoolEntry]:
        group = memo.owner(loop_id)
        assembles = [a for a in memo.members(group)
                     if memo.node(a).op == "assemble"
                     and memo.provenance.get(a, (None, None))[1] == loop_id]
        if len(assembles) != 1:
            return None         # no F-IR form, or an unexpected shape
        aid = assembles[0]
        child_gids = memo.canonical_children(aid)
        if len(set(child_gids)) != len(child_gids):
            return None         # var groups merged with each other: skip
        var_groups: List[Tuple[_SlotRec, ...]] = []
        for g in child_gids:
            members = memo.members(g)       # and-id order = creation order
            index = {m: i for i, m in enumerate(members)}
            recs: List[_SlotRec] = []
            for m in members:
                node = memo.node(m)
                if node.op not in _SLOT_OPS or node.children:
                    return None  # merged with a non-slot group: skip
                prov = memo.provenance.get(m)
                if prov is None:
                    rule, src = None, -1
                else:
                    rule, src_id = prov
                    if src_id == loop_id:
                        src = -1
                    elif src_id in index and index[src_id] < index[m]:
                        src = index[src_id]
                    else:
                        return None  # provenance crosses groups: skip
                recs.append(_SlotRec(node.op, node.payload, rule, src))
            var_groups.append(tuple(recs))
        a_prov = memo.provenance.get(aid)
        return _PoolEntry(assemble_payload=memo.node(aid).payload,
                          assemble_rule=a_prov[0] if a_prov else None,
                          var_groups=tuple(var_groups))
