"""Deterministic, sharded, resumable synthetic data pipeline.

Index-based and stateless per shard: batch `i` for host shard (r, W) is a
pure function of (seed, i, r, W) — so

  * resume is exact (the checkpoint stores only the step counter);
  * a re-joined or replacement host recomputes its shard without any
    coordination (straggler/failure recovery at 1000+ nodes);
  * elastic re-sharding (changing W) changes batch composition but never
    replays or skips data within a shard schedule.

A background prefetch thread keeps `prefetch` batches ready so host-side
generation overlaps device compute.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["PipelineConfig", "SyntheticLM", "Prefetcher"]


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    shard_rank: int = 0
    shard_count: int = 1
    emb_dim: Optional[int] = None     # frontend-stub archs: emit embeddings
    enc_dec: bool = False

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.shard_count == 0
        return self.global_batch // self.shard_count


class SyntheticLM:
    """Markov-ish synthetic token stream (enough structure that loss falls)."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, index, c.shard_rank, c.shard_count]))
        B, T = c.local_batch, c.seq_len
        # structured stream: each row is an arithmetic token sequence
        # t_{i+1} = t_i + b (b ∈ {0,1}) with 2% noise — constant rows give a
        # trivially learnable copy-previous-token signal so smoke training
        # shows loss movement within tens of steps
        b = rng.integers(0, 2, (B, 1))
        t0 = rng.integers(0, c.vocab_size, (B, 1))
        steps = np.arange(T)[None, :]
        toks = (t0 + b * steps) % c.vocab_size
        noise = rng.random((B, T)) < 0.02
        toks = np.where(noise, rng.integers(0, c.vocab_size, (B, T)), toks)
        toks = toks.astype(np.int32)
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1).astype(np.int32)
        out = {"tokens": toks, "labels": labels,
               "positions": np.broadcast_to(steps, (B, T)).astype(np.int32)}
        if c.emb_dim:
            out["embeds"] = rng.standard_normal((B, T, c.emb_dim)).astype(np.float32)
            del out["tokens"]
        if c.enc_dec:
            out["enc_embeds"] = rng.standard_normal(
                (B, T, c.emb_dim or 1024)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


class Prefetcher:
    """Background thread keeping `depth` batches ready; resumable via
    ``state()`` / ``restore()`` (just the next index)."""

    def __init__(self, source: SyntheticLM, start_index: int = 0,
                 depth: int = 2):
        self.source = source
        self._next = start_index
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        i = self._next
        while not self._stop.is_set():
            try:
                self._q.put((i, self.source.batch(i)), timeout=0.2)
                i += 1
            except queue.Full:
                continue

    def get(self):
        i, b = self._q.get()
        self._next = i + 1
        return b

    def state(self) -> Dict:
        return {"next_index": self._next}

    @staticmethod
    def restore(source: SyntheticLM, state: Dict, depth: int = 2
                ) -> "Prefetcher":
        return Prefetcher(source, start_index=state["next_index"], depth=depth)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=1.0)
