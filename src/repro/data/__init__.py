from .pipeline import PipelineConfig, Prefetcher, SyntheticLM
__all__ = ["PipelineConfig", "Prefetcher", "SyntheticLM"]
