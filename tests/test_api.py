"""The unified CobraSession API: tracing frontend, config, compile/run.

Acceptance from the redesign issue:
  * the ``ProgramBuilder`` trace produces IR byte-identical to hand-built
    Region trees;
  * ``CobraSession.compile()`` + ``Executable.run()`` reproduce the paper's
    P0 → P1/P2 rewrites end-to-end — same chosen plans and simulated costs
    as the legacy ``optimize()`` free function;
  * the session fronts the distributed TPU planner with the same
    ``PlanReport`` result vocabulary.
"""

import numpy as np
import pytest

from repro.api import (CobraSession, Executable, ExecutionResult,
                       OptimizerConfig, PlanReport, ProgramBuilder, col,
                       param, q)
from repro.core import CostCatalog, optimize
from repro.core.regions import (Assign, BasicBlock, CollectionAdd, CondRegion,
                                IBin, ICall, IConst, IEmptyList, IField,
                                ILoadAll, INav, IVar, LoopRegion, Program,
                                UpdateRow, seq)
from repro.programs import (make_m0, make_orders_customer_db, make_p0,
                            make_sales_db, make_wilos_db, make_wilos_e)
from repro.relational.database import FAST_LOCAL, SLOW_REMOTE


# --------------------------------------------------------------------------
# ProgramBuilder: trace == hand-built IR
# --------------------------------------------------------------------------

def hand_built_p0() -> Program:
    """Fig. 3a exactly as the pre-API code assembled it."""
    body = seq(
        Assign("cust", INav(IVar("o"), "o_customer_sk", "customer",
                            "c_customer_sk")),
        Assign("val", ICall("myFunc", (IField(IVar("o"), "o_id"),
                                       IField(IVar("cust"), "c_birth_year")))),
        CollectionAdd("result", IVar("val")),
    )
    return Program(
        "P0",
        seq(Assign("result", IEmptyList()),
            LoopRegion("o", ILoadAll("orders"), body, label="L3-7")),
        outputs=("result",),
    )


def hand_built_wilos_a() -> Program:
    inner = LoopRegion(
        "y", ILoadAll("tasks"),
        CondRegion(IBin("==", IField(IVar("y"), "t_role_id"),
                        IField(IVar("x"), "r_id")),
                   BasicBlock(Assign("cnt", IBin("+", IVar("cnt"), IConst(1))))))
    outer_body = seq(
        Assign("cnt", IConst(0)),
        inner,
        UpdateRow("roles", "r_rank", IVar("cnt"), "r_id",
                  IField(IVar("x"), "r_id")),
    )
    return Program("W_A", seq(LoopRegion("x", ILoadAll("roles"), outer_body)),
                   outputs=())


class TestProgramBuilder:
    def test_p0_trace_matches_hand_built(self):
        assert make_p0().key() == hand_built_p0().key()

    def test_wilos_a_trace_matches_hand_built(self):
        from repro.programs import make_wilos_a
        assert make_wilos_a().key() == hand_built_wilos_a().key()

    def test_single_statement_scopes_stay_unwrapped(self):
        """A one-region loop body / cond branch is NOT seq-wrapped (matches
        how the hand-built programs nested regions)."""
        b = ProgramBuilder("t")
        r = b.let("r", b.empty_list())
        with b.loop(b.load_all("tasks"), var="t") as t:
            with b.when(t.t_state == 1):
                b.add(r, t.t_hours)
        p = b.build(outputs=(r,))
        loop = p.body.parts[1]
        assert isinstance(loop, LoopRegion)
        assert isinstance(loop.body, CondRegion)              # not SeqRegion
        assert isinstance(loop.body.then_r, BasicBlock)       # not SeqRegion

    def test_operator_tracing(self):
        b = ProgramBuilder("t")
        x = b.var("x")
        e = (x + 1) * 2 == x.f
        assert e.ir.key() == IBin("==", IBin("*", IBin("+", IVar("x"),
                                                       IConst(1)), IConst(2)),
                                  IField(IVar("x"), "f")).key()

    def test_expr_has_no_truth_value(self):
        b = ProgramBuilder("t")
        with pytest.raises(TypeError, match="when"):
            bool(b.var("x") == 1)

    def test_unclosed_scope_rejected(self):
        b = ProgramBuilder("t")
        cm = b.loop(b.load_all("tasks"), var="t")
        cm.__enter__()
        with pytest.raises(RuntimeError, match="unclosed"):
            b.build()

    def test_otherwise_requires_when(self):
        b = ProgramBuilder("t")
        with pytest.raises(RuntimeError, match="otherwise"):
            with b.otherwise():
                pass

    def test_otherwise_fills_else_branch(self):
        b = ProgramBuilder("t")
        n = b.let("n", 0)
        with b.loop(b.load_all("tasks"), var="t") as t:
            with b.when(t.t_state == 1):
                b.let("n", n + 1)
            with b.otherwise():
                b.let("n", n + 2)
        p = b.build(outputs=(n,))
        cond = p.body.parts[1].body
        assert isinstance(cond, CondRegion) and cond.else_r is not None

    def test_query_handles_compose(self):
        h = q("tasks").where(col("t_role_id").eq(param("rid"))) \
                      .select("t_hours").order_by("t_hours").limit(5)
        assert "WHERE" in h.sql() and "LIMIT 5" in h.sql()
        bound = h.bind(rid=IVar("w"))
        assert bound.bindings == (("rid", IVar("w")),)


# --------------------------------------------------------------------------
# OptimizerConfig
# --------------------------------------------------------------------------

class TestOptimizerConfig:
    def test_preset_paper_excludes_t3(self):
        names = OptimizerConfig.preset("paper-exp1-3").rule_names()
        assert "T3" not in names and "T1" in names

    def test_preset_full_has_every_rule(self):
        from repro.core.rules import default_rules
        assert set(OptimizerConfig.preset("full").rule_names()) == \
            {r.name for r in default_rules()}

    def test_unknown_preset_and_rule_rejected(self):
        with pytest.raises(ValueError, match="preset"):
            OptimizerConfig.preset("nope")
        with pytest.raises(ValueError, match="unknown rule"):
            OptimizerConfig(rules=("T1", "bogus")).resolve_rules()

    def test_invalid_choice_rejected(self):
        with pytest.raises(ValueError, match="choice"):
            OptimizerConfig(choice="vibes")

    def test_preset_overrides(self):
        cfg = OptimizerConfig.preset("paper-exp1-3", topk=2)
        assert cfg.topk == 2 and cfg.exclude_rules == ("T3",)


# --------------------------------------------------------------------------
# Session compile/run ≡ legacy optimize()
# --------------------------------------------------------------------------

def legacy_paper_rules():
    from repro.core.rules import default_rules
    return [r for r in default_rules() if r.name != "T3"]


class TestSessionEndToEnd:
    @pytest.mark.parametrize("n_orders,n_cust,expect", [
        (100, 5000, "JOIN"),        # Experiment 1: P0 -> P1
        (4000, 500, "prefetch"),    # Experiment 2: P0 -> P2
    ])
    def test_p0_rewrites_match_optimize(self, n_orders, n_cust, expect):
        db = make_orders_customer_db(n_orders, n_cust)
        legacy = optimize(make_p0(), db, CostCatalog(SLOW_REMOTE),
                          rules=legacy_paper_rules())
        session = CobraSession(db, CostCatalog(SLOW_REMOTE),
                               config=OptimizerConfig.preset("paper-exp1-3"))
        exe = session.compile(make_p0())
        assert expect in repr(exe.program.body)
        # same chosen plan and simulated cost as the legacy entry point —
        # codegen names are alpha-normalized per run, so two searches of the
        # same program emit byte-identical IR
        assert exe.program.body.key() == legacy.program.body.key()
        assert exe.est_cost_s == pytest.approx(legacy.est_cost)

    def test_run_is_semantics_preserving_and_faster(self):
        db = make_orders_customer_db(500, 100)
        session = CobraSession(db, CostCatalog(SLOW_REMOTE))
        p0 = make_p0()
        base = session.execute(p0)
        exe = session.compile(p0)
        out = exe.run()
        a = np.sort(np.asarray(base["result"], dtype=np.float64))
        c = np.sort(np.asarray(out["result"], dtype=np.float64))
        assert np.allclose(a, c, rtol=1e-4)
        assert out.simulated_s <= base.simulated_s
        assert isinstance(out, ExecutionResult) and out.n_queries >= 1

    def test_execute_many_with_params(self):
        db = make_wilos_db(500, ratio=10)
        session = CobraSession(db, CostCatalog(FAST_LOCAL))
        exe = session.compile(make_wilos_e())
        r1 = exe.run(worklist=[1, 3])
        r2 = exe.run(worklist=[2])
        r3 = exe.run(worklist=[1, 3])
        assert exe.n_runs == 3 and session.executions == 3
        assert sorted(r1["result"]) == sorted(r3["result"])
        assert len(r2["result"]) != len(r1["result"])

    def test_m0_single_query_via_session(self):
        db = make_sales_db(2000)
        session = CobraSession(db, CostCatalog(SLOW_REMOTE))
        out = session.compile(make_m0()).run()
        assert out.n_queries == 1
        base = session.execute(make_m0())
        assert out["total"] == pytest.approx(base["total"], rel=1e-4)

    def test_heuristic_config_refuses_prefetch(self):
        from repro.programs import make_wilos_a
        db = make_wilos_db(1000)
        session = CobraSession(db, CostCatalog(FAST_LOCAL))
        exe_c = session.compile(make_wilos_a())
        exe_h = session.compile(make_wilos_a(),
                                config=OptimizerConfig.preset("heuristic"))
        assert "prefetch" in repr(exe_c.program.body)
        assert "prefetch" not in repr(exe_h.program.body)

    def test_report_vocabulary(self):
        db = make_orders_customer_db(100, 100)
        session = CobraSession(db, CostCatalog(SLOW_REMOTE))
        rep = session.compile(make_p0()).report
        assert isinstance(rep, PlanReport) and rep.domain == "program"
        assert rep.alternatives >= 1 and rep.est_cost_s > 0
        assert "P0" in rep.describe()

    def test_codegen_alpha_normalized_across_sessions(self):
        """Two independent sessions compiling the same program emit
        byte-identical rewritten IR (content-stable codegen names) — the
        property the cross-session plan store's dedupe rests on."""
        exes = []
        for _ in range(2):
            db = make_orders_customer_db(4000, 500)
            session = CobraSession(db, CostCatalog(SLOW_REMOTE),
                                   config=OptimizerConfig.preset("paper-exp1-3"))
            exes.append(session.compile(make_p0()))
        assert exes[0].program.body.key() == exes[1].program.body.key()


# --------------------------------------------------------------------------
# session.trace() decorator
# --------------------------------------------------------------------------

class TestTraceDecorator:
    def _session(self):
        return CobraSession(make_wilos_db(300, ratio=10),
                            CostCatalog(FAST_LOCAL))

    def test_trace_turns_function_into_executable(self):
        session = self._session()

        @session.trace
        def task_hours(b, worklist=()):
            out = b.let("out", b.empty_list())
            with b.loop(worklist, var="wid") as wid:
                per_key = q("tasks").where(col("t_role_id").eq(param("rid"))) \
                                    .bind(rid=wid)
                with b.loop(per_key, var="y") as y:
                    b.add(out, y.t_hours)
            return out

        assert isinstance(task_hours, Executable)
        # the traced program matches the hand-built equivalent (make_wilos_e)
        src = task_hours.source
        assert src.inputs == (("worklist", ()),)
        r1 = task_hours.run(worklist=[1, 3])
        r2 = session.compile(make_wilos_e()).run(worklist=[1, 3])
        assert sorted(r1["out"]) == sorted(r2["result"])

    def test_trace_with_name_and_multiple_outputs(self):
        session = self._session()

        @session.trace(name="two_aggs")
        def f(b):
            n = b.let("n", 0)
            hours = b.let("hours", 0.0)
            with b.loop(b.load_all("tasks"), var="t") as t:
                b.let("n", n + 1)
                b.let("hours", hours + t.t_hours)
            return n, hours

        assert f.source.name == "two_aggs"
        out = f.run()
        assert out["n"] == session.db.table("tasks").nrows
        assert out["hours"] > 0

    def test_trace_hits_plan_cache(self):
        session = self._session()

        def body(b):
            total = b.let("total", 0.0)
            with b.loop(b.load_all("tasks"), var="t") as t:
                b.let("total", total + t.t_hours)
            return total

        exe1 = session.trace(body, name="agg")
        exe2 = session.trace(body, name="agg")
        assert not exe1.from_cache and exe2.from_cache


# --------------------------------------------------------------------------
# Distributed-planner facade (shared vocabulary)
# --------------------------------------------------------------------------

class TestPlannerFacade:
    def test_plan_step_matches_core_planner(self):
        from repro.core.planner import plan as core_plan
        from repro.models.arch import get_arch
        session = CobraSession(make_orders_customer_db(10, 10))
        rep = session.plan_step("stablelm-12b", 2048, 64, "train")
        raw = core_plan(get_arch("stablelm-12b"), 2048, 64, "train")
        assert isinstance(rep, PlanReport) and rep.domain == "step"
        assert rep.choice == raw["choice"]
        assert rep.est_cost_s == pytest.approx(raw["cost_s"])
        assert rep.alternatives == raw["n_alternatives"]

    def test_plan_step_keyed_on_hardware_profile(self):
        """An HW-table override is part of the step-plan memo key, like the
        cost catalog is for program plans: the same cell re-planned on
        different hardware must not be served the stale report."""
        from repro.analysis.roofline import HW
        session = CobraSession(make_orders_customer_db(10, 10))
        r1 = session.plan_step("rwkv6-3b", 1024, 4, "decode")
        old = HW["hbm_bw"]
        try:
            HW["hbm_bw"] = old / 4
            r2 = session.plan_step("rwkv6-3b", 1024, 4, "decode")
            assert r2 is not r1          # fresh planning pass, not the memo
            r3 = session.plan_step("rwkv6-3b", 1024, 4, "decode")
            assert r3 is r2              # memoized under the NEW profile
        finally:
            HW["hbm_bw"] = old
        assert session.plan_step("rwkv6-3b", 1024, 4, "decode") is r1

    def test_plan_step_memoized_and_topk(self):
        session = CobraSession(make_orders_customer_db(10, 10))
        r1 = session.plan_step("rwkv6-3b", 1024, 4, "decode")
        r2 = session.plan_step("rwkv6-3b", 1024, 4, "decode")
        assert r1 is r2  # facade memoizes identical cells
        top3 = session.plan_step("rwkv6-3b", 1024, 4, "decode", top_k=3)
        assert len(top3) == 3
        assert top3[0].est_cost_s <= top3[1].est_cost_s <= top3[2].est_cost_s
        # alternatives reports the enumerated space, not the truncated top-k
        from repro.core.planner import enumerate_plans
        from repro.models.arch import get_arch
        n_space = len(enumerate_plans(get_arch("rwkv6-3b"), "decode"))
        assert all(rep.alternatives == n_space for rep in top3)
        assert n_space > 3


# --------------------------------------------------------------------------
# Back-compat shim
# --------------------------------------------------------------------------

def test_optimize_shim_unchanged_signature():
    """repro.core.optimize keeps its exact legacy behaviour (tier-1 tests
    elsewhere exercise it heavily); it now routes through a session."""
    db = make_orders_customer_db(200, 400)
    res = optimize(make_p0(), db, CostCatalog(SLOW_REMOTE))
    assert res.est_cost > 0 and res.opt_time_s < 1.0
    assert res.program.outputs == ("result",)
