"""The compiled execution tier: lowering, tier selection, promotion,
invalidation, and the anti-regression swap guard.

Issue acceptance:
  * compiled execution is BIT-IDENTICAL to interpreted execution — outputs
    AND the simulated clock / query / round-trip telemetry — for every
    example program, on every available backend;
  * identity survives concurrent ``analyze()`` and table writes landing
    mid-stream (epoch-keyed probe indices rebuild, artifacts invalidate);
  * ``CompileManager`` promotes a hot (program, plan, context) pair only
    after the configured number of interpreted invocations, caches the
    artifact content-addressed, and drops it when its tables drift;
  * regions outside the columnar vocabulary (``while`` guards, early
    exits, nested loops, update bodies) stay on the interpreter — the
    splicing is the fallback, never an error;
  * a drift-triggered plan swap is replayed against the last observed
    bindings and REJECTED when the old plan is actually cheaper.
"""

import types

import pytest

from repro.api import CobraSession, OptimizerConfig
from repro.compiled import (CompileManager, available_backends, lower_program,
                            resolve_backend)
from repro.core import CostCatalog
from repro.programs import (make_m0, make_orders_customer_db, make_p0,
                            make_p1, make_p2, make_sales_db, make_scan,
                            make_wilos_a, make_wilos_b, make_wilos_c,
                            make_wilos_d, make_wilos_e, make_wilos_f,
                            make_wilos_db)
from repro.relational.database import FAST_LOCAL, SLOW_REMOTE
from repro.runtime import ServingRuntime

# (factory, db factory, param sets) per example program
PROGRAMS = {
    "P0": (make_p0, lambda: make_orders_customer_db(300, 30), [{}] * 3),
    "P1": (make_p1, lambda: make_orders_customer_db(300, 30), [{}] * 3),
    "P2": (make_p2, lambda: make_orders_customer_db(300, 30), [{}] * 3),
    "M0": (make_m0, lambda: make_sales_db(200), [{}] * 3),
    "SCAN": (make_scan, lambda: make_wilos_db(200), [{}] * 3),
    "W_A": (make_wilos_a, lambda: make_wilos_db(120), [{}] * 2),
    "W_B": (make_wilos_b, lambda: make_wilos_db(200), [{}] * 3),
    "W_C": (make_wilos_c, lambda: make_wilos_db(120), [{}] * 2),
    "W_D": (make_wilos_d, lambda: make_wilos_db(200), [{}] * 3),
    "W_E": (make_wilos_e, lambda: make_wilos_db(200),
            [{"worklist": [0, 1, 2]}, {"worklist": [1]}, {"worklist": []}]),
    "W_F": (make_wilos_f, lambda: make_wilos_db(200), [{}] * 3),
}


def session(db, network=SLOW_REMOTE):
    return CobraSession(db, CostCatalog(network))


def run_tier(name, tier, backend=None, monkeypatch=None):
    make, mkdb, params = PROGRAMS[name]
    sess = session(mkdb())
    exe = sess.compile(make())
    if backend is not None and monkeypatch is not None:
        monkeypatch.setenv("REPRO_COMPILED_BACKEND", backend)
    return exe.run_batch(params, tier=tier)


def assert_batches_identical(a, b):
    assert a.n_queries == b.n_queries
    assert a.n_round_trips == b.n_round_trips
    assert a.simulated_s == b.simulated_s
    assert len(a.results) == len(b.results)
    for ra, rb in zip(a.results, b.results):
        assert ra.outputs == rb.outputs
        assert ra.simulated_s == rb.simulated_s
        assert ra.n_queries == rb.n_queries
        assert ra.n_round_trips == rb.n_round_trips


# --------------------------------------------------------------------------
# Tier parity: compiled == interpreted, bit for bit and tick for tick
# --------------------------------------------------------------------------

class TestTierParity:
    @pytest.mark.parametrize("name", sorted(PROGRAMS))
    @pytest.mark.parametrize("backend", available_backends())
    def test_program_identical_across_tiers(self, name, backend, monkeypatch):
        interp = run_tier(name, "interpreter")
        compiled = run_tier(name, "compiled", backend, monkeypatch)
        assert interp.tier == "interpreter"
        assert compiled.tier == "compiled"
        assert_batches_identical(interp, compiled)

    def test_backends_agree(self, monkeypatch):
        if len(available_backends()) < 2:
            pytest.skip("only one backend importable")
        a = run_tier("P0", "compiled", "kernels", monkeypatch)
        b = run_tier("P0", "compiled", "numpy", monkeypatch)
        assert_batches_identical(a, b)

    def test_identity_under_mid_stream_analyze_and_write(self):
        """An analyze() and a table write landing BETWEEN compiled batches
        must leave compiled results identical to a pure-interpreter twin
        seeing the same interleaving (epoch keys rebuild probe indices)."""
        outs = {}
        for tier in ("interpreter", "compiled"):
            db = make_orders_customer_db(300, 30)
            sess = session(db)
            exe = sess.compile(make_p0())
            batches = [exe.run_batch([{}] * 3, tier=tier)]
            db.analyze()                                  # stats epoch moves
            batches.append(exe.run_batch([{}] * 3, tier=tier))
            orders = db.table("orders")
            db.replace_table(orders.head(orders.nrows - 20))
            batches.append(exe.run_batch([{}] * 3, tier=tier))
            outs[tier] = batches
        for a, b in zip(outs["interpreter"], outs["compiled"]):
            assert_batches_identical(a, b)

    def test_epoch_moves_rebuild_probe_index(self):
        # the raw (unoptimized) P0: its navigation loop lowers to the nav
        # hook, whose probe index is epoch-cached
        from repro.runtime import BatchClientEnv
        db = make_orders_customer_db(200, 20)
        lowered = lower_program(make_p0())
        assert lowered.n_columnar >= 1
        cl = next(iter(lowered._loops.values()))
        env = BatchClientEnv(db, SLOW_REMOTE)
        lowered.run(env)
        first = cl.index_rebuilds
        assert first >= 1                       # cold index built once
        lowered.run(env)
        assert cl.index_rebuilds == first       # warm: epoch unchanged
        db.analyze("customer")
        lowered.run(env)
        assert cl.index_rebuilds > first        # epoch moved: rebuilt


# --------------------------------------------------------------------------
# Lowering: verdicts, tiered fallback, backend resolution
# --------------------------------------------------------------------------

class TestLowering:
    def test_scan_keeps_while_on_interpreter(self):
        sess = session(make_wilos_db(100))
        exe = sess.compile(make_scan())
        lowered = exe.lower()
        # the while guard and early exit are interpreter regions, yet the
        # program still runs (splicing fallback), so lowering never errors
        assert lowered.interpreter_regions >= 1

    def test_nested_loops_lower_to_zero_columnar(self):
        sess = session(make_wilos_db(100))
        exe = sess.compile(make_wilos_c())
        lowered = exe.lower()
        # W_C's winner either rewrites the nest away (columnar loop) or
        # keeps it (0 columnar loops) — both are valid; what matters is
        # that nested regions never get a columnar binding they can't run
        assert lowered.n_columnar >= 0
        assert "columnar loop" in lowered.describe()

    def test_executable_lower_is_memoized(self):
        sess = session(make_orders_customer_db(100, 10))
        exe = sess.compile(make_p0())
        assert exe.lower() is exe.lower()

    def test_resolve_backend_env_override(self, monkeypatch):
        monkeypatch.setenv("REPRO_COMPILED_BACKEND", "numpy")
        assert resolve_backend() == "numpy"
        # explicit request beats the environment
        assert resolve_backend(available_backends()[0]) == \
            available_backends()[0]

    def test_resolve_backend_rejects_unknown(self):
        with pytest.raises(ValueError):
            resolve_backend("cuda")

    def test_run_batch_rejects_unknown_tier(self):
        sess = session(make_orders_customer_db(50, 5))
        exe = sess.compile(make_p0())
        with pytest.raises(ValueError):
            exe.run_batch([{}], tier="gpu")


# --------------------------------------------------------------------------
# CompileManager: promotion, artifact cache, invalidation
# --------------------------------------------------------------------------

class TestCompileManager:
    def _exe(self, n=150):
        sess = session(make_orders_customer_db(n, 15))
        return sess, sess.compile(make_p0())

    def test_promotion_threshold(self):
        sess, exe = self._exe()
        mgr = CompileManager(sess, threshold=3)
        assert mgr.lowered_for(exe, n_invocations=1) is None
        assert mgr.lowered_for(exe, n_invocations=1) is None
        lowered = mgr.lowered_for(exe, n_invocations=1)
        assert lowered is not None and lowered.n_columnar >= 1
        assert mgr.compiles == 1
        # further calls hit the artifact cache, no recompile
        assert mgr.lowered_for(exe) is lowered
        assert mgr.compiles == 1

    def test_batch_heat_promotes_immediately(self):
        sess, exe = self._exe()
        mgr = CompileManager(sess, threshold=4)
        # one 8-invocation batch crosses the threshold on its own
        assert mgr.lowered_for(exe, n_invocations=8) is not None

    def test_invalidate_tables_drops_artifact_and_heat(self):
        sess, exe = self._exe()
        mgr = CompileManager(sess, threshold=1)
        assert mgr.lowered_for(exe) is not None
        assert mgr.invalidate_tables(["orders"]) >= 1
        # artifact gone AND heat reset: next call starts cold again at
        # threshold 2
        mgr.threshold = 2
        assert mgr.lowered_for(exe, n_invocations=1) is None

    def test_zero_columnar_lowering_cached_as_noop(self):
        sess = session(make_wilos_db(80))
        exe = sess.compile(make_wilos_a())       # mutating nest: no columnar
        lowered = exe.lower()
        if lowered.n_columnar:
            pytest.skip("winner lowered W_A to a columnar form")
        mgr = CompileManager(sess, threshold=1)
        assert mgr.lowered_for(exe) is None
        assert mgr.noop_lowerings == 1
        assert mgr.lowered_for(exe) is None      # cached noop: not re-lowered
        assert mgr.noop_lowerings == 1
        assert mgr.telemetry()["noop_lowerings"] == 1

    def test_telemetry_keys(self):
        sess, exe = self._exe()
        mgr = CompileManager(sess, threshold=1)
        mgr.lowered_for(exe)
        t = mgr.telemetry()
        for k in ("backend", "threshold", "compiles", "compile_s_total",
                  "compiled_batches", "interpreted_batches",
                  "hot_candidates"):
            assert k in t


# --------------------------------------------------------------------------
# Serving integration: hot promotion, drift invalidation, swap guard
# --------------------------------------------------------------------------

class TestServingCompiledTier:
    def _runtime(self, compile_hot_plans=2, **kw):
        sess = session(make_orders_customer_db(300, 30),
                       network=FAST_LOCAL)
        rt = ServingRuntime(sess, batch_size=8,
                            compile_hot_plans=compile_hot_plans, **kw)
        rt.register(make_p0())
        return rt

    def test_hot_promotion_and_parity(self):
        reqs = [("P0", {})] * 24
        rt = self._runtime()
        out = rt.serve(reqs)
        t = rt.telemetry()
        assert t["compiled_compiles"] >= 1
        assert t["compiled_compiled_batches"] >= 1
        assert t["session_compiled_executions"] >= 8
        rt2 = self._runtime(compile_hot_plans=None)
        assert rt2.compiler is None
        out2 = rt2.serve(reqs)
        assert all(a.outputs == b.outputs and a.simulated_s == b.simulated_s
                   for a, b in zip(out, out2))

    def test_config_knob_enables_tier(self):
        sess = CobraSession(make_orders_customer_db(100, 10),
                            CostCatalog(FAST_LOCAL),
                            config=OptimizerConfig(compile_hot_plans=1))
        rt = ServingRuntime(sess, batch_size=4)
        assert rt.compiler is not None and rt.compiler.threshold == 1

    def test_compile_knob_not_in_cache_key(self):
        a = OptimizerConfig().cache_key()
        b = OptimizerConfig(compile_hot_plans=5).cache_key()
        assert a == b


class TestSwapGuard:
    def _feedback_session(self):
        db = make_orders_customer_db(400, 40)
        sess = session(db)                      # SLOW_REMOTE: N+1 is painful
        from repro.runtime.feedback import FeedbackController
        return sess, FeedbackController(sess, 3.0)

    def _fake_exe(self, program):
        return types.SimpleNamespace(program=program, source=program)

    def test_regressing_swap_rejected(self):
        sess, fb = self._feedback_session()
        good = self._fake_exe(sess.compile(make_p0()).program)  # optimized
        bad = self._fake_exe(make_p0())         # the raw N+1 original
        assert fb.validate_swap(bad, good, [{}]) is True
        assert fb.validate_swap(good, bad, [{}]) is False
        assert fb.swaps_rejected == 1 and fb.swaps_accepted == 1
        assert sess.plan_swaps_rejected == 1
        assert sess.plan_swaps_accepted == 1
        rejected = [s for s in fb.swap_log if not s["accepted"]]
        assert rejected and \
            rejected[0]["new_replay_s"] > rejected[0]["old_replay_s"]

    def test_no_bindings_accepts_without_replay(self):
        sess, fb = self._feedback_session()
        a = self._fake_exe(make_p0())
        b = self._fake_exe(sess.compile(make_p0()).program)
        assert fb.validate_swap(a, b, []) is True
        assert fb.swap_log[-1]["replayed"] == 0

    def test_mutating_program_accepts_without_replay(self):
        db = make_wilos_db(100)
        sess = session(db)
        from repro.runtime.feedback import FeedbackController
        fb = FeedbackController(sess, 3.0)
        wa = self._fake_exe(make_wilos_a())     # issues UPDATEs
        other = self._fake_exe(sess.compile(make_wilos_a()).program)
        version_before = db.site_epoch(("roles",))
        assert fb.validate_swap(wa, other, [{}]) is True
        assert fb.swap_log[-1]["replayed"] == 0
        # the guard must not have written the live database
        assert db.site_epoch(("roles",)) == version_before

    def test_serving_guarded_swap_counts_rejections(self):
        sess = session(make_orders_customer_db(300, 30))
        rt = ServingRuntime(sess, batch_size=4)
        rt.register(make_p0())
        rt.serve([("P0", {})] * 4)              # seeds the replay window
        bad = sess.compile(make_p0())
        bad = types.SimpleNamespace(program=make_p0(), source=make_p0(),
                                    from_cache=False)
        rt._guarded_swap("P0", bad)
        assert rt.swaps_rejected == 1
        assert rt.executable("P0") is not bad   # old plan kept serving
