"""Sharded multi-worker serving cluster (repro.cluster).

Issue acceptance:
  * ``ClusterRuntime.serve()`` is bit-identical to single-worker
    ``ServingRuntime.serve()`` for every example program — outputs AND
    final database state — including under mid-stream writes,
    ``analyze()``, and drift-triggered plan swaps;
  * horizontal partitioning: scatter-gather merges (ordered merge /
    partial-aggregate combine) are bit-exact per query shape; equality on
    the partition key prunes to one shard; replicated tables never
    scatter;
  * per-shard ``site_epoch``/``data_version`` semantics: a direct write to
    ONE shard moves the coordinator epoch; ``replace_table`` on one shard
    keeps merged-view order; a mutating program touching rows on two
    shards still applies exactly;
  * deadline-driven batch formation (flush on deadline-expiry or
    max-batch) and the worker's published formed-batch context;
  * shared plan store warm-starts across workers; merged metrics
    reconcile bit-for-bit with per-worker sums; triage carries per-shard
    share and skew columns.
"""

import tempfile

import numpy as np
import pytest

from repro.api import CobraSession
from repro.api.lift import lift_program, load_all, update_row
from repro.cluster import (BatchFormer, ClusterRuntime, GPOS, Partitioner,
                           Request, Router, ShardedDatabase, uniform_arrivals)
from repro.obs.trace import Tracer
from repro.obs.triage import render_triage
from repro.programs import (make_scan, make_wilos_a, make_wilos_db,
                            make_wilos_e, make_wilos_f)
from repro.relational.algebra import (Aggregate, AggSpec, BoolOp, Cmp, Col,
                                      Join, Limit, Lit, OrderBy, Param,
                                      Project, Scan, Select)
from repro.relational.database import DatabaseServer
from repro.runtime import ServingRuntime


def fresh_db(n=1000, seed=5):
    src = make_wilos_db(n, seed=seed)
    return DatabaseServer(dict(src.tables), src.model)


def sharded(n_shards, n=1000, seed=5):
    return ShardedDatabase.shard(fresh_db(n, seed), n_shards,
                                 keys={"tasks": "t_role_id"})


def assert_tables_equal(t0, t1, ctx=""):
    assert t1.schema.names == t0.schema.names, ctx
    for c in t0.schema.names:
        a, b = np.asarray(t0.column(c)), np.asarray(t1.column(c))
        assert a.dtype == b.dtype, (ctx, c, a.dtype, b.dtype)
        assert np.array_equal(a, b), (ctx, c)


# --------------------------------------------------------------------------
# Partitioner
# --------------------------------------------------------------------------

class TestPartitioner:
    def test_split_preserves_rows_and_order(self):
        db = fresh_db(300)
        p = Partitioner(4, {"tasks": "t_role_id"})
        t = db.table("tasks")
        parts = p.split(t)
        assert sum(q.nrows for q in parts) == t.nrows
        for k, q in enumerate(parts):
            assert q.schema.has(GPOS)
            roles = np.asarray(q.column("t_role_id"))
            assert np.all(roles % 4 == k)
            g = np.asarray(q.column(GPOS))
            # rows keep their global relative order inside a partition
            assert np.all(np.diff(g) > 0)
        # gpos values partition the full index space exactly
        allg = np.sort(np.concatenate(
            [np.asarray(q.column(GPOS)) for q in parts]))
        assert np.array_equal(allg, np.arange(t.nrows))

    def test_gpos_does_not_change_row_bytes(self):
        db = fresh_db(100)
        p = Partitioner(2, {"tasks": "t_role_id"})
        part = p.split(db.table("tasks"))[0]
        assert part.row_bytes == db.table("tasks").row_bytes

    def test_replicated_tables(self):
        db = fresh_db(100)
        p = Partitioner(3, {"tasks": "t_role_id"})
        copies = p.shard_tables(db.table("roles"))
        assert len(copies) == 3
        for c in copies:
            assert c.nrows == db.table("roles").nrows
            assert not c.schema.has(GPOS)
        assert p.shard_of("roles", 5) is None
        assert p.shard_of("tasks", 7) == 7 % 3


# --------------------------------------------------------------------------
# ShardedDatabase: query bit-identity
# --------------------------------------------------------------------------

QUERY_SHAPES = [
    ("scan_part", Scan("tasks"), None),
    ("scan_repl", Scan("roles"), None),
    ("prune_lit", Select(Cmp("==", Col("t_role_id"), Lit(7)),
                         Scan("tasks")), None),
    ("prune_param", Select(Cmp("==", Col("t_role_id"), Param("rid")),
                           Scan("tasks")), {"rid": 11}),
    ("prune_and", Select(BoolOp("and",
                                Cmp("==", Col("t_role_id"), Lit(5)),
                                Cmp("<", Col("t_state"), Lit(3))),
                         Scan("tasks")), None),
    ("scatter_select", Select(Cmp("<", Col("t_state"), Lit(2)),
                              Scan("tasks")), None),
    ("scatter_project", Project(("t_id", "t_state"),
                                Select(Cmp("<", Col("t_state"), Lit(2)),
                                       Scan("tasks"))), None),
    ("join_part_repl", Join(Scan("tasks"), Scan("roles"),
                            "t_role_id", "r_id"), None),
    ("join_repl_part", Join(Scan("roles"), Scan("tasks"),
                            "r_id", "t_role_id"), None),
    ("agg_grouped_combinable",
     Aggregate(("t_state",), (AggSpec("count", None, "n"),
                              AggSpec("min", "t_id", "lo"),
                              AggSpec("max", "t_id", "hi"),
                              AggSpec("sum", "t_role_id", "s")),
               Scan("tasks")), None),
    ("agg_grouped_float_sum",
     Aggregate(("t_state",), (AggSpec("sum", "t_hours", "h"),),
               Scan("tasks")), None),
    ("agg_global_combinable",
     Aggregate((), (AggSpec("count", None, "n"),
                    AggSpec("max", "t_id", "hi")), Scan("tasks")), None),
    ("agg_global_float",
     Aggregate((), (AggSpec("sum", "t_hours", "h"),
                    AggSpec("avg", "t_hours", "a")), Scan("tasks")), None),
    ("agg_grouped_int_avg",
     Aggregate(("t_state",), (AggSpec("avg", "t_role_id", "a"),
                              AggSpec("count", None, "n")),
               Scan("tasks")), None),
    ("agg_global_int_avg",
     Aggregate((), (AggSpec("avg", "t_id", "a"),
                    AggSpec("sum", "t_role_id", "s")), Scan("tasks")), None),
    ("agg_int_avg_empty_input",
     Aggregate((), (AggSpec("avg", "t_role_id", "a"),),
               Select(Cmp("==", Col("t_state"), Lit(99)),
                      Scan("tasks"))), None),
    ("agg_empty_input",
     Aggregate((), (AggSpec("sum", "t_hours", "h"),),
               Select(Cmp("==", Col("t_state"), Lit(99)),
                      Scan("tasks"))), None),
    ("orderby_limit", Limit(10, OrderBy(("t_state", "t_id"),
                                        Scan("tasks"))), None),
]


class TestShardedQueries:
    @pytest.mark.parametrize("n_shards", [1, 2, 4])
    @pytest.mark.parametrize(
        "tag,query,params", QUERY_SHAPES, ids=[s[0] for s in QUERY_SHAPES])
    def test_bit_identical_to_unsharded(self, n_shards, tag, query, params):
        base = fresh_db()
        sh = sharded(n_shards)
        r0, _, _ = base.run(query, params)
        r1, _, _ = sh.run(query, params)
        assert_tables_equal(r0, r1, tag)
        assert not any(c.endswith(GPOS) for c in r1.schema.names)

    def test_prune_routes_to_single_shard(self):
        sh = sharded(4)
        q = Select(Cmp("==", Col("t_role_id"), Lit(6)), Scan("tasks"))
        sh.run(q)
        assert sh.pruned_queries == 1
        assert sh.scattered_queries == 0
        assert sh.shard_queries[6 % 4] == 1

    def test_replicated_only_never_scatters(self):
        sh = sharded(4)
        sh.run(Scan("roles"))
        assert sh.replicated_queries == 1
        assert sh.scattered_queries == 0

    def test_float_sum_never_partial_combines(self):
        # float addition is order-sensitive: sum(t_hours) must gather the
        # child rows and fold them in the unsharded order, not combine
        # per-shard partials
        sh = sharded(4)
        node = Aggregate((), (AggSpec("sum", "t_hours", "h"),),
                         Scan("tasks"))
        assert not sh._combinable(node)
        intnode = Aggregate((), (AggSpec("sum", "t_role_id", "s"),),
                            Scan("tasks"))
        assert sh._combinable(intnode)

    def test_int_avg_partial_combines_float_avg_does_not(self):
        # avg over an int column ships (sum, count) partials — both add
        # exactly — and divides once at the coordinator; avg over a float
        # column would inherit float-sum order sensitivity, so it gathers
        sh = sharded(4)
        node = Aggregate(("t_state",),
                         (AggSpec("avg", "t_role_id", "a"),), Scan("tasks"))
        assert sh._combinable(node)
        fnode = Aggregate(("t_state",),
                          (AggSpec("avg", "t_hours", "a"),), Scan("tasks"))
        assert not sh._combinable(fnode)

    def test_int_avg_uses_scatter_path_and_stays_bit_exact(self):
        base = fresh_db()
        sh = sharded(4)
        node = Aggregate(("t_state",), (AggSpec("avg", "t_role_id", "a"),
                                        AggSpec("sum", "t_id", "s")),
                         Scan("tasks"))
        before = sh.scattered_queries
        r0, _, _ = base.run(node)
        r1, _, _ = sh.run(node)
        assert sh.scattered_queries == before + 1
        assert_tables_equal(r0, r1, "grouped int avg")
        # the (sum, count) partial-state columns never leak to the caller
        assert all("__av" not in c for c in r1.schema.names)
        assert dict(zip(r1.schema.names,
                        (f.dtype for f in r1.schema.fields)))["a"] \
            == "float32"

    def test_estimates_match_unsharded(self):
        base = fresh_db()
        sh = sharded(4)
        q = Select(Cmp("==", Col("t_role_id"), Lit(3)), Scan("tasks"))
        e0, e1 = base.estimate(q), sh.estimate(q)
        assert e0 == e1
        assert base.stats_fingerprint(["tasks", "roles"]) == \
            sh.stats_fingerprint(["tasks", "roles"])


# --------------------------------------------------------------------------
# ShardedDatabase: writes, per-shard epochs (issue satellite)
# --------------------------------------------------------------------------

class TestShardedWrites:
    def test_direct_shard_write_moves_coordinator_epoch(self):
        sh = sharded(4)
        e0 = sh.site_epoch(("tasks",))
        r0 = sh.site_epoch(("roles",))
        dv0 = sh.data_version("tasks")
        sv0 = sh.shard_versions("tasks")
        part = sh.shards[1].table("tasks")
        sh.shards[1].replace_table(part.head(max(1, part.nrows // 2)))
        sv1 = sh.shard_versions("tasks")
        # only shard 1's data version moved...
        assert sv1[1][1] == sv0[1][1] + 1
        assert [v for i, v in enumerate(sv1) if i != 1] == \
            [v for i, v in enumerate(sv0) if i != 1]
        # ...and the summed coordinator epoch moved with it
        assert sh.data_version("tasks") == dv0 + 1
        assert sh.site_epoch(("tasks",)) != e0
        # an untouched table's epoch stays put
        assert sh.site_epoch(("roles",)) == r0
        # the merged view reflects the shrunken shard
        roles = np.asarray(sh.table("tasks").column("t_role_id"))
        assert np.count_nonzero(roles % 4 == 1) == max(1, part.nrows // 2)

    def test_replace_table_on_one_shard_remerges_in_order(self):
        sh = sharded(2)
        before = sh.table("tasks")
        part = sh.shards[0].table("tasks")
        keep = np.arange(part.nrows // 2)
        sh.shards[0].replace_table(part.take(keep))
        after = sh.table("tasks")
        assert after.nrows == before.nrows - (part.nrows - len(keep))
        # surviving rows keep their original relative order
        ids_before = list(np.asarray(before.column("t_id")))
        ids_after = list(np.asarray(after.column("t_id")))
        it = iter(ids_before)
        assert all(any(x == y for y in it) for x in ids_after)

    def test_coordinator_replace_keeps_stats_stale(self):
        base = fresh_db()
        sh = sharded(4)
        q = Scan("tasks")
        small = base.table("tasks").head(50)
        base.replace_table(small)
        sh.replace_table(small)
        # estimates still from the OLD stats — identically stale
        assert base.estimate(q) == sh.estimate(q)
        r0, _, _ = base.run(q)
        r1, _, _ = sh.run(q)
        assert_tables_equal(r0, r1, "post-replace")
        base.analyze("tasks")
        sh.analyze("tasks")
        assert base.estimate(q) == sh.estimate(q)
        assert base.stats_fingerprint(["tasks"]) == \
            sh.stats_fingerprint(["tasks"])

    def test_mutating_program_touching_two_shards(self):
        # one program whose UPDATEs key on t_role_id values living on
        # DIFFERENT shards: every row must land exactly as unsharded
        def W2():
            for x in load_all("roles"):
                update_row("tasks", "t_state", x.r_rank,
                           "t_role_id", x.r_id)
        prog = lift_program(W2)

        base = fresh_db()
        CobraSession(base).compile(prog).run()

        sh = sharded(2)
        CobraSession(sh).compile(prog).run()
        assert_tables_equal(base.table("tasks"), sh.table("tasks"),
                            "two-shard update")
        # the write re-partitioned: each shard holds only its own keys
        for k, s in enumerate(sh.shards):
            roles = np.asarray(s.table("tasks").column("t_role_id"))
            assert np.all(roles % 2 == k)


# --------------------------------------------------------------------------
# Router + BatchFormer
# --------------------------------------------------------------------------

class TestRouter:
    def test_affinity_routes_by_key_identity(self):
        r = Router(4, {"W_E": "worklist"})
        assert r.route("W_E", {"worklist": [6]}) == 6 % 4
        assert r.route("W_E", {"worklist": [6, 99]}) == 6 % 4
        assert r.route("W_E", {"worklist": [9]}) == 9 % 4
        assert r.affinity_routed == 3

    def test_hash_routing_is_deterministic(self):
        a = Router(4)
        b = Router(4)
        for i in range(20):
            params = {"x": i, "y": [i, i + 1]}
            assert a.route("P", params) == b.route("P", params)

    def test_skew_measures_hot_worker(self):
        r = Router(4, {"P": "k"})
        for _ in range(12):
            r.route("P", {"k": 8})   # 8 % 4 == 0: everything on worker 0
        assert r.skew() == pytest.approx(4.0)
        u = Router(4, {"P": "k"})
        for i in range(12):
            u.route("P", {"k": i})
        assert u.skew() == pytest.approx(1.0)


class TestBatchFormer:
    def test_burst_flushes_full_batches(self):
        f = BatchFormer(deadline_s=0.01, max_batch=8)
        reqs = [Request(i, "P", {}, worker=0) for i in range(20)]
        batches = f.form(reqs)
        assert [b.size for b in batches] == [8, 8, 4]
        assert [b.reason for b in batches] == ["full", "full", "deadline"]
        # request order is preserved through forming
        assert [r.index for b in batches for r in b.requests] == \
            list(range(20))

    def test_sparse_arrivals_flush_on_deadline(self):
        f = BatchFormer(deadline_s=0.05, max_batch=64)
        arr = uniform_arrivals(10, rps=50.0)   # 20ms apart
        reqs = [Request(i, "P", {}, worker=0, arrival_s=arr[i])
                for i in range(10)]
        batches = f.form(reqs)
        assert all(b.reason == "deadline" for b in batches)
        assert all(b.size < 64 for b in batches)
        assert sum(b.size for b in batches) == 10
        # a queue's flush time is its oldest member + deadline
        assert batches[0].flush_s == pytest.approx(arr[0] + 0.05)

    def test_forming_is_deterministic(self):
        reqs = [Request(i, "PQ"[i % 2], {}, worker=i % 3,
                        arrival_s=0.001 * (i % 5)) for i in range(30)]
        a = BatchFormer(deadline_s=0.002, max_batch=4).form(reqs)
        b = BatchFormer(deadline_s=0.002, max_batch=4).form(reqs)
        assert [(x.worker, x.program, x.flush_s, x.reason,
                 tuple(r.index for r in x.requests)) for x in a] == \
               [(x.worker, x.program, x.flush_s, x.reason,
                 tuple(r.index for r in x.requests)) for x in b]


# --------------------------------------------------------------------------
# ClusterRuntime: the non-negotiable invariant
# --------------------------------------------------------------------------

def example_stream(n=30):
    reqs = []
    for i in range(n):
        reqs.append(("W_E", {"worklist": [i % 7]}))
        if i % 10 == 0:
            reqs.append(("W_F", {}))
        if i % 11 == 3:
            reqs.append(("W_A", {}))       # mid-stream writes
        if i % 13 == 6:
            reqs.append(("SCAN", {}))      # while-loop + early exit
    return reqs


def serve_single(reqs, batch_size=8, mid=None):
    db = fresh_db()
    rt = ServingRuntime(CobraSession(db), batch_size=batch_size)
    for mk in (make_wilos_e, make_wilos_f, make_wilos_a, make_scan):
        rt.register(mk())
    if mid is None:
        return rt.serve(reqs), db, rt
    out = rt.serve(reqs[:len(reqs) // 2])
    mid(db)
    out += rt.serve(reqs[len(reqs) // 2:])
    return out, db, rt


def serve_cluster(reqs, n_workers, store=None, mid=None, **kw):
    cl = ClusterRuntime(fresh_db(), n_workers=n_workers,
                        partition_keys={"tasks": "t_role_id"},
                        affinity={"W_E": "worklist"},
                        deadline_s=0.01, max_batch=8, store=store, **kw)
    for mk in (make_wilos_e, make_wilos_f, make_wilos_a, make_scan):
        cl.register(mk())
    if mid is None:
        return cl.serve(reqs), cl
    out = cl.serve(reqs[:len(reqs) // 2])
    mid(cl.db)
    out += cl.serve(reqs[len(reqs) // 2:])
    return out, cl


def assert_bit_identical(r_single, db_single, r_cluster, cl):
    assert len(r_single) == len(r_cluster)
    for i, (a, b) in enumerate(zip(r_single, r_cluster)):
        assert a.outputs == b.outputs, f"request {i} outputs diverged"
    for name in db_single.tables:
        assert_tables_equal(db_single.table(name), cl.db.table(name), name)


class TestClusterBitIdentity:
    @pytest.mark.parametrize("n_workers", [1, 2, 4])
    def test_mixed_stream_with_writes(self, n_workers):
        reqs = example_stream()
        r1, db1, _ = serve_single(reqs)
        r2, cl = serve_cluster(reqs, n_workers)
        assert_bit_identical(r1, db1, r2, cl)

    def test_mid_stream_analyze(self):
        reqs = example_stream(24)
        r1, db1, _ = serve_single(reqs, mid=lambda db: db.analyze())
        r2, cl = serve_cluster(reqs, 2, mid=lambda db: db.analyze())
        assert_bit_identical(r1, db1, r2, cl)

    def test_drift_triggered_replans(self):
        # a mid-stream bulk replace (no ANALYZE) makes every estimate
        # stale; the feedback controllers detect the drift, re-analyze,
        # and may swap plans — outputs must not budge
        def grow(db):
            t = db.table("tasks")
            db.replace_table(t.take(np.tile(np.arange(t.nrows), 4)))

        reqs = example_stream(24)
        r1, db1, rt1 = serve_single(reqs, mid=grow)
        r2, cl = serve_cluster(reqs, 2, mid=grow)
        assert_bit_identical(r1, db1, r2, cl)
        moved = rt1.recompiles + sum(w.recompiles for w in cl.workers)
        assert moved > 0  # the drift machinery actually fired

    def test_responses_in_request_order(self):
        reqs = [("W_E", {"worklist": [i % 5]}) for i in range(17)]
        r2, cl = serve_cluster(reqs, 4)
        db = fresh_db()
        session = CobraSession(db)
        exe = session.compile(make_wilos_e())
        for i, res in enumerate(r2):
            assert res.outputs == exe.run(worklist=[i % 5]).outputs


# --------------------------------------------------------------------------
# ClusterRuntime: formed batches drive the serving context
# --------------------------------------------------------------------------

class TestFormedBatchContext:
    def test_worker_publishes_observed_batch_size(self):
        cl = ClusterRuntime(fresh_db(), n_workers=1,
                            partition_keys={"tasks": "t_role_id"},
                            deadline_s=0.01, max_batch=64)
        cl.register(make_wilos_e())
        # a sparse stream forms batches of 1: the worker must stop costing
        # plans for batch 64 and republish the observed size
        reqs = [("W_E", {"worklist": [i]}) for i in range(6)]
        cl.serve(reqs, arrivals=uniform_arrivals(6, rps=10.0))
        w = cl.workers[0]
        assert w.batch_publishes >= 1
        assert w._base_context.batch_size < 64
        h = w.metrics.histogram("formed_batch_size")
        assert h is not None and h["count"] >= 1

    def test_burst_forms_max_batches(self):
        cl = ClusterRuntime(fresh_db(), n_workers=1,
                            partition_keys={"tasks": "t_role_id"},
                            deadline_s=0.01, max_batch=16)
        cl.register(make_wilos_e())
        reqs = [("W_E", {"worklist": [3]}) for _ in range(32)]
        cl.serve(reqs)
        assert cl.former.flushes_full == 2
        assert cl.workers[0]._formed_sizes.count(16) == 2


class TestFormationPlanFlip:
    """The deadline-driven former reaches the batch-64 SCAN plan flip with
    no fixed-size batch configuration anywhere — and the default
    bit-identity guard vetoes exactly that flip, because the batch-1 and
    batch-64 SCAN plans differ in float low bits."""

    def _build(self, **kw):
        from repro.api import OptimizerConfig
        from repro.core import CostCatalog
        from repro.relational.database import SLOW_REMOTE
        return ClusterRuntime(fresh_db(), n_workers=1,
                              partition_keys={"tasks": "t_role_id"},
                              deadline_s=0.01, max_batch=64,
                              initial_batch_size=1,
                              catalog=CostCatalog(SLOW_REMOTE),
                              config=OptimizerConfig.preset("paper-exp1-3"),
                              **kw)

    def test_burst_reaches_batch64_flip(self):
        # guard off + feedback off isolates the formation->publish->
        # recompile mechanism: the worker starts costed for batch 1 (the
        # per-iteration query plan), the burst forms one batch of 64, the
        # published context flips the plan to the amortized prefetch
        cl = self._build(bit_guard_swaps=False, feedback=False)
        cl.register(make_scan())
        w = cl.workers[0]
        assert w._base_context.batch_size == 1       # initial_batch_size
        assert "prefetch" not in repr(w.executable("SCAN").program.body)
        cl.serve([("SCAN", {}) for _ in range(64)])
        assert cl.former.flushes_full == 1
        assert w.batch_publishes >= 1
        assert w._base_context.batch_size == 64
        assert "prefetch" in repr(w.executable("SCAN").program.body)

    def test_default_bit_guard_vetoes_divergent_flip(self):
        # same burst under defaults: the publish still happens, but the
        # guard replays the candidate and vetoes the swap (the prefetch
        # plan's float64 client fold differs from the query plan's float32
        # DB-side SUM in the low bits), so outputs stay bit-identical to
        # batch-1 single-worker serving
        from repro.api import OptimizerConfig
        from repro.core import CostCatalog
        from repro.relational.database import SLOW_REMOTE
        cl = self._build()
        cl.register(make_scan())
        w = cl.workers[0]
        out = cl.serve([("SCAN", {}) for _ in range(64)])
        assert w.bit_vetoes >= 1
        assert w.swaps_rejected >= 1
        assert "prefetch" not in repr(w.executable("SCAN").program.body)
        rt = ServingRuntime(
            CobraSession(fresh_db(), catalog=CostCatalog(SLOW_REMOTE),
                         config=OptimizerConfig.preset("paper-exp1-3")),
            batch_size=1)
        rt.register(make_scan())
        ref = rt.serve([("SCAN", {}) for _ in range(64)])
        assert [r.outputs for r in out] == [r.outputs for r in ref]


# --------------------------------------------------------------------------
# Shared plan store, metrics aggregation, triage, tracing
# --------------------------------------------------------------------------

class TestClusterObservability:
    def test_shared_store_warm_starts_other_workers(self):
        with tempfile.TemporaryDirectory() as d:
            cl = ClusterRuntime(fresh_db(), n_workers=4,
                                partition_keys={"tasks": "t_role_id"},
                                store=d)
            cl.register(make_wilos_e())
            # the first worker searches; the shared store hands the same
            # plan to the remaining three
            assert cl.store.hits >= 3

    def test_metrics_reconcile_with_worker_sums(self):
        r2, cl = serve_cluster(example_stream(20), 3)
        snap = cl.metrics_snapshot()
        assert snap["workers_serving_requests_served"] == \
            sum(w.requests_served for w in cl.workers)
        assert snap["workers_serving_batches_run"] == \
            sum(w.batches_run for w in cl.workers)
        assert snap["workers_serving_simulated_s"] == pytest.approx(
            sum(w.simulated_s for w in cl.workers))
        assert snap["cluster_requests_served"] == len(r2)
        # structured dumps stay associative over workers
        from repro.obs.metrics import combine_snapshots
        dumps = cl.metrics_dump()
        left = combine_snapshots(combine_snapshots(dumps[0], dumps[1]),
                                 dumps[2])
        right = combine_snapshots(dumps[0],
                                  combine_snapshots(dumps[1], dumps[2]))
        assert left == right

    def test_triage_flags_hot_shard_under_skew(self):
        cl = ClusterRuntime(fresh_db(), n_workers=4,
                            partition_keys={"tasks": "t_role_id"},
                            affinity={"W_E": "worklist"}, max_batch=8)
        cl.register(make_wilos_e())
        # every key ≡ 0 (mod 4): all traffic piles onto worker 0
        cl.serve([("W_E", {"worklist": [4 * (i % 3)]}) for i in range(24)])
        rows = cl.triage()
        row = next(r for r in rows if r.name == "W_E")
        assert row.shard_requests == (24, 0, 0, 0)
        assert row.hot_shard == 0
        assert row.skew == pytest.approx(4.0)
        rendered = render_triage(rows)
        assert "hot" in rendered and "skew" in rendered
        assert "24/0/0/0" in rendered

    def test_tracer_sees_flush_and_scatter_spans(self):
        tracer = Tracer()
        cl = ClusterRuntime(fresh_db(), n_workers=2,
                            partition_keys={"tasks": "t_role_id"},
                            affinity={"W_E": "worklist"},
                            max_batch=4, tracer=tracer)
        cl.register(make_wilos_e())
        cl.serve([("W_E", {"worklist": [i]}) for i in range(8)])
        names = {s.name for s in tracer.spans()}
        assert "cluster_serve" in names
        assert "flush" in names
        assert "scatter-gather" in names

    def test_telemetry_shape(self):
        r2, cl = serve_cluster(example_stream(12), 2)
        t = cl.telemetry()
        assert t["requests_served"] == len(r2)
        assert len(t["worker_requests"]) == 2
        assert sum(t["worker_requests"]) == len(r2)
        assert t["router_routed"] == len(r2)
        assert t["makespan_s"] > 0
