"""The plain-Python frontend: AST lifting, while/early-exit regions.

Issue acceptance:
  * every paper program in ``repro.programs``, written as a plain Python
    function, lifts to Region IR **byte-identical** (same ``Program.key()``
    and fingerprint) to the hand-built region trees;
  * a while/early-exit program (SCAN) compiles, executes correctly under
    both ``run()`` and ``run_batch()`` (per-invocation early exit), and
    shows a cost-based rewrite win in its PlanReport;
  * rendering a generated builder program as plain Python and lifting it
    round-trips to identical IR keys (property test, hypothesis-gated);
  * unsupported constructs raise ``LiftError`` diagnostics that point at
    the offending source line.
"""

import numpy as np
import pytest

from repro.api import (CobraSession, Executable, LiftError, ProgramBuilder,
                       col, lift_program, lift_source, load_all, param,
                       prefetch, program_fingerprint, q)
from repro.core import CostCatalog
from repro.core.regions import (BasicBlock, CondRegion, IBin, IConst, IField,
                                IVar, Program, WhileRegion, get_function)
from repro.programs import (ORDERS_CUSTOMER_REL, make_m0,
                            make_orders_customer_db, make_p0, make_p1, make_p2,
                            make_scan, make_wilos_a, make_wilos_b,
                            make_wilos_c, make_wilos_d, make_wilos_db,
                            make_wilos_e, make_wilos_f)
from repro.relational.database import FAST_LOCAL, SLOW_REMOTE

myFunc = get_function("myFunc")
combine = get_function("combine")
scale = get_function("scale")

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # optional dev dependency (see pyproject.toml)
    HAS_HYPOTHESIS = False


# --------------------------------------------------------------------------
# Byte-identical: plain-Python programs == builder-built trees
# --------------------------------------------------------------------------
# The builder versions below are the pre-lifter renditions of every paper
# program (the exact code that used to live in repro.programs); the lifted
# plain-Python versions must emit the same Region IR byte for byte.

def builder_p0() -> Program:
    b = ProgramBuilder("P0")
    b.relate("orders", "o_customer_sk", "customer", "c_customer_sk",
             name="customer")
    result = b.let("result", b.empty_list())
    with b.loop(b.load_all("orders"), var="o") as o:
        cust = b.let("cust", o.customer)
        val = b.let("val", b.call("myFunc", o.o_id, cust.c_birth_year))
        b.add(result, val)
    return b.build(outputs=(result,))


def builder_p1() -> Program:
    b = ProgramBuilder("P1")
    join = q("orders").join("customer", "o_customer_sk", "c_customer_sk")
    result = b.let("result", b.empty_list())
    with b.loop(join, var="r") as r:
        val = b.let("val", b.call("myFunc", r.o_id, r.c_birth_year))
        b.add(result, val)
    return b.build(outputs=(result,))


def builder_p2() -> Program:
    b = ProgramBuilder("P2")
    result = b.let("result", b.empty_list())
    b.prefetch("customer", by="c_customer_sk")
    with b.loop(b.load_all("orders"), var="o") as o:
        cust = b.let("cust", b.cache_lookup("customer", "c_customer_sk",
                                            o.o_customer_sk))
        val = b.let("val", b.call("myFunc", o.o_id, cust.c_birth_year))
        b.add(result, val)
    return b.build(outputs=(result,))


def builder_m0() -> Program:
    b = ProgramBuilder("M0")
    monthly = q("sales").select("month", "sale_amt").order_by("month")
    total = b.let("total", 0.0)
    csum = b.let("cSum", b.empty_map())
    with b.loop(monthly, var="t") as t:
        b.let("total", total + t.sale_amt)
        b.put(csum, t.month, total)
    return b.build(outputs=(total, csum))


def builder_wilos_a() -> Program:
    b = ProgramBuilder("W_A")
    with b.loop(b.load_all("roles"), var="x") as x:
        cnt = b.let("cnt", 0)
        with b.loop(b.load_all("tasks"), var="y") as y:
            with b.when(y.t_role_id == x.r_id):
                b.let("cnt", cnt + 1)
        b.update_row("roles", "r_rank", cnt, "r_id", x.r_id)
    return b.build(outputs=())


def builder_wilos_b() -> Program:
    b = ProgramBuilder("W_B")
    n = b.let("n", 0)
    items = b.let("items", b.empty_list())
    with b.loop(b.load_all("tasks"), var="t") as t:
        b.let("n", n + 1)
        b.add(items, b.call("scale", t.t_hours))
    return b.build(outputs=(n, items))


def builder_wilos_c() -> Program:
    b = ProgramBuilder("W_C")
    result = b.let("result", b.empty_list())
    with b.loop(b.load_all("tasks"), var="x") as x:
        with b.loop(b.load_all("roles"), var="y") as y:
            with b.when(y.r_id == x.t_role_id):
                b.add(result, b.call("combine", x.t_hours, y.r_rank))
    return b.build(outputs=(result,))


def builder_wilos_d() -> Program:
    b = ProgramBuilder("W_D")
    result = b.let("result", b.empty_list())
    with b.loop(b.load_all("roles"), var="x") as x:
        s = b.let("s", 0.0)
        tasks_of_role = q("tasks").where(col("t_role_id").eq(param("rid"))) \
                                  .bind(rid=x.r_id)
        with b.loop(tasks_of_role, var="y") as y:
            b.let("s", s + y.t_hours)
        b.add(result, s)
    return b.build(outputs=(result,))


def builder_wilos_e() -> Program:
    b = ProgramBuilder("W_E")
    worklist = b.input("worklist", ())
    result = b.let("result", b.empty_list())
    with b.loop(worklist, var="wid") as wid:
        per_key = q("tasks").where(col("t_role_id").eq(param("rid"))) \
                            .bind(rid=wid)
        with b.loop(per_key, var="y") as y:
            b.add(result, y.t_hours)
    return b.build(outputs=(result,))


def builder_wilos_f() -> Program:
    b = ProgramBuilder("W_F")
    hours = b.let("hours", 0.0)
    with b.loop(q("tasks").select("t_hours"), var="a") as a:
        b.let("hours", hours + a.t_hours)
    states = b.let("states", 0)
    with b.loop(q("tasks").select("t_state"), var="b") as row:
        b.let("states", states + row.t_state)
    return b.build(outputs=(hours, states))


def builder_scan() -> Program:
    b = ProgramBuilder("SCAN")
    threshold = b.input("threshold", 100.0)
    max_state = b.input("max_state", 5)
    state = b.let("state", 0)
    total = b.let("total", 0.0)
    with b.while_(state < max_state):
        s = b.let("s", 0.0)
        per_state = q("tasks").where(col("t_state").eq(param("k"))) \
                              .bind(k=state)
        with b.loop(per_state, var="t") as t:
            b.let("s", s + t.t_hours)
        b.let("total", total + s)
        b.let("state", state + 1)
        with b.when(total > threshold):
            b.brk()
    return b.build(outputs=(total, state))


PAPER_PAIRS = [
    ("P0", make_p0, builder_p0), ("P1", make_p1, builder_p1),
    ("P2", make_p2, builder_p2), ("M0", make_m0, builder_m0),
    ("W_A", make_wilos_a, builder_wilos_a),
    ("W_B", make_wilos_b, builder_wilos_b),
    ("W_C", make_wilos_c, builder_wilos_c),
    ("W_D", make_wilos_d, builder_wilos_d),
    ("W_E", make_wilos_e, builder_wilos_e),
    ("W_F", make_wilos_f, builder_wilos_f),
    ("SCAN", make_scan, builder_scan),
]


class TestByteIdenticalLifting:
    @pytest.mark.parametrize("name,lifted,hand", PAPER_PAIRS,
                             ids=[p[0] for p in PAPER_PAIRS])
    def test_program_key_and_fingerprint_match(self, name, lifted, hand):
        lp, hp = lifted(), hand()
        assert lp.key() == hp.key()
        assert program_fingerprint(lp) == program_fingerprint(hp)
        assert lp.inputs == hp.inputs

    def test_lifted_inputs_carry_defaults(self):
        p = make_scan()
        assert p.inputs == (("threshold", 100.0), ("max_state", 5))


# --------------------------------------------------------------------------
# Lowering details
# --------------------------------------------------------------------------

class TestLoweringDetails:
    def test_augmented_assignment_matches_plain_form(self):
        def f_plain():
            total = 0.0
            for t in load_all("tasks"):
                total = total + t.t_hours
            return total

        def f_aug():
            total = 0.0
            for t in load_all("tasks"):
                total += t.t_hours
            return total

        assert lift_program(f_plain, name="F").key() == \
            lift_program(f_aug, name="F").key()

    def test_static_left_operand_preserves_order(self):
        def f():
            n = 0
            for t in load_all("tasks"):
                if 2 < t.t_state:
                    n = n + 1
            return n

        p = lift_program(f)
        cond = p.body.parts[1].body
        assert cond.pred.key() == IBin("<", IConst(2),
                                       IField(IVar("t"), "t_state")).key()

    def test_elif_chain_lowers_to_nested_otherwise(self):
        def f():
            n = 0
            for t in load_all("tasks"):
                if t.t_state == 0:
                    n = n + 1
                elif t.t_state == 1:
                    n = n + 2
                else:
                    n = n + 3
            return n

        p = lift_program(f)
        cond = p.body.parts[1].body
        assert isinstance(cond, CondRegion) and cond.else_r is not None
        assert isinstance(cond.else_r, CondRegion)
        assert cond.else_r.else_r is not None

    def test_continue_lowers_and_executes(self):
        def f():
            n = 0
            for t in load_all("tasks"):
                if t.t_state == 0:
                    continue
                n = n + 1
            return n

        p = lift_program(f)
        body = p.body.parts[1].body
        assert isinstance(body.then_r if isinstance(body, CondRegion)
                          else body.parts[0].then_r, BasicBlock)
        db = make_wilos_db(200)
        session = CobraSession(db, CostCatalog(FAST_LOCAL))
        n_not0 = int((np.asarray(db.table("tasks").column("t_state")) != 0).sum())
        assert session.execute(p)["n"] == n_not0
        assert session.execute(p, mode="exact")["n"] == n_not0

    def test_early_return_stops_execution(self):
        def f():
            n = 0
            for t in load_all("tasks"):
                n = n + 1
                if n >= 7:
                    return n
            return n

        p = lift_program(f)
        db = make_wilos_db(300)
        session = CobraSession(db, CostCatalog(FAST_LOCAL))
        assert session.execute(p)["n"] == 7
        assert session.execute(p, mode="exact")["n"] == 7

    def test_return_expression_gets_canonical_name(self):
        def f():
            total = 0.0
            for t in load_all("tasks"):
                total = total + t.t_hours
            return total * 2

        p = lift_program(f)
        assert p.outputs == ("_ret0",)
        db = make_wilos_db(100)
        session = CobraSession(db, CostCatalog(FAST_LOCAL))
        out = session.execute(p)
        hours = float(np.asarray(db.table("tasks").column("t_hours"),
                                 dtype=np.float64).sum())
        assert out["_ret0"] == pytest.approx(2 * hours, rel=1e-5)

    def test_mixed_return_sites_converge_on_canonical_names(self):
        def f():
            n = 0
            for t in load_all("tasks"):
                n = n + 1
                if n >= 3:
                    return n + 100
            return n + 200

        p = lift_program(f)
        db = make_wilos_db(100)
        session = CobraSession(db, CostCatalog(FAST_LOCAL))
        assert session.execute(p)["_ret0"] == 103

    def test_closure_scalar_becomes_constant(self):
        cap = 17

        def f():
            n = 0
            for t in load_all("tasks"):
                n = n + cap
            return n

        p = lift_program(f)
        body = p.body.parts[1].body
        assert body.stmt.expr.key() == IBin("+", IVar("n"), IConst(17)).key()

    def test_user_helper_shadowing_registered_name_errors_loudly(self):
        """A local callable that happens to share a registered function's
        name must NOT be silently replaced by the registry entry."""
        def scale(x):  # shadows the registered "scale" with different math
            return x * 1000

        def f():
            out = []
            for t in load_all("tasks"):
                out.append(scale(t.t_hours))
            return out

        with pytest.raises(LiftError, match="register_function"):
            lift_program(f)

    def test_registered_alias_same_object_still_traces(self):
        my_scale = scale  # the registered callable itself, under its name

        def f():
            out = []
            for t in load_all("tasks"):
                out.append(my_scale(t.t_hours))
            return out

        assert "scale(" in repr(lift_program(f).body)

    def test_return_of_trace_time_binding_rejected(self):
        """Returning a name bound to a trace-time value (a query handle)
        must raise, not silently compile to a None output."""
        def f():
            rows = q("tasks").select("t_hours")
            return rows

        with pytest.raises(LiftError, match="trace-time"):
            lift_program(f)

    def test_lift_source_keyword_only_params(self):
        src = """
def F(a=1, *, limit=3):
    n = 0
    for t in load_all("tasks"):
        n = n + limit + a
    return n
"""
        p = lift_source(src, env={"load_all": load_all})
        assert p.inputs == (("a", 1), ("limit", 3))
        session = CobraSession(make_wilos_db(50), CostCatalog(FAST_LOCAL))
        rows = session.db.table("tasks").nrows
        assert session.execute(p)["n"] == 4 * rows
        assert session.execute(p, limit=5, a=0)["n"] == 5 * rows

    def test_registered_function_reached_through_binding(self):
        fn = scale  # a registered callable bound to a local name

        def f():
            out = []
            for t in load_all("tasks"):
                out.append(fn(t.t_hours))
            return out

        p = lift_program(f)
        assert "scale(" in repr(p.body)

    def test_while_true_with_break(self):
        def f():
            n = 0
            while True:
                n = n + 1
                if n >= 4:
                    break
            return n

        p = lift_program(f)
        w = p.body.parts[1]
        assert isinstance(w, WhileRegion) and w.pred.key() == IConst(True).key()
        session = CobraSession(make_wilos_db(10), CostCatalog(FAST_LOCAL))
        assert session.execute(p)["n"] == 4

    def test_lift_source_matches_lift_program(self):
        src = """
def F(worklist=()):
    out = []
    for wid in worklist:
        for y in q("tasks").where(col("t_role_id").eq(param("r"))).bind(r=wid):
            out.append(y.t_hours)
    return out
"""
        p = lift_source(src, env={"q": q, "col": col, "param": param})

        def F(worklist=()):
            out = []
            for wid in worklist:
                for y in q("tasks").where(col("t_role_id")
                                          .eq(param("r"))).bind(r=wid):
                    out.append(y.t_hours)
            return out

        assert p.key() == lift_program(F).key()
        assert p.inputs == (("worklist", ()),)


# --------------------------------------------------------------------------
# LiftError diagnostics
# --------------------------------------------------------------------------

class TestLiftErrors:
    def _raises(self, fn, match, **kw):
        with pytest.raises(LiftError, match=match) as ei:
            lift_program(fn, **kw)
        assert "ProgramBuilder" in str(ei.value)  # escape hatch named
        return ei

    def test_generator_expression_rejected_with_location(self):
        """List/set/dict comprehensions lift (TestListComprehensions,
        TestDictSetComprehensions); generator expressions stay outside the
        vocabulary."""
        def f():
            xs = list(t.t_id for t in load_all("tasks"))
            return xs

        ei = self._raises(f, match="generator expressions")
        assert "test_lift.py" in str(ei.value)

    def test_unknown_name(self):
        def f():
            n = 0
            for t in load_all("tasks"):
                n = n + undefined_thing  # noqa: F821
            return n

        self._raises(f, match="unknown name 'undefined_thing'")

    def test_unregistered_call_on_traced_values(self):
        # a small pure helper is INLINED now (see test_inline.py); the
        # register_function guidance still fires for callables the inliner
        # cannot even consider (no Python source, e.g. a bound builtin)
        def helper(x):
            return x * 2

        def f():
            out = []
            for t in load_all("tasks"):
                out.append(helper(t.t_hours))
            return out

        assert lift_program(f).body is not None

        import math

        def g():
            out = []
            for t in load_all("tasks"):
                out.append(math.floor(t.t_hours))
            return out

        self._raises(g, match="register_function")

    def test_nested_function_rejected(self):
        def f():
            def g():
                return 1
            return g()

        self._raises(f, match="nested function")

    def test_trace_time_constant_condition(self):
        def f():
            n = 0
            if 1 < 2:
                n = 1
            return n

        self._raises(f, match="trace-time constant")

    def test_chained_comparison(self):
        def f():
            n = 0
            for t in load_all("tasks"):
                if 0 < t.t_state < 3:
                    n = n + 1
            return n

        self._raises(f, match="chained comparison")

    def test_statement_marker_in_expression_position(self):
        def f():
            x = prefetch("tasks", by="t_id")
            return x

        self._raises(f, match="statement, not an expression")

    def test_return_arity_mismatch(self):
        def f():
            n = 0
            for t in load_all("tasks"):
                if t.t_state == 0:
                    return n
            return n, 1

        self._raises(f, match="arity mismatch")

    def test_marker_called_outside_tracing(self):
        with pytest.raises(LiftError, match="tracing marker"):
            load_all("tasks")

    def test_source_unavailable(self):
        fn = eval("lambda: 1")
        with pytest.raises(LiftError, match="source"):
            lift_program(fn)


# --------------------------------------------------------------------------
# session.trace: plain-Python mode + builder escape hatch
# --------------------------------------------------------------------------

class TestTracePlainPython:
    def test_trace_plain_function(self):
        session = CobraSession(make_wilos_db(300, ratio=10),
                               CostCatalog(FAST_LOCAL))

        @session.trace
        def hours(worklist=()):
            out = []
            for wid in worklist:
                for y in q("tasks").where(col("t_role_id")
                                          .eq(param("r"))).bind(r=wid):
                    out.append(y.t_hours)
            return out

        assert isinstance(hours, Executable)
        r1 = hours.run(worklist=[1, 3])
        r2 = session.compile(make_wilos_e()).run(worklist=[1, 3])
        assert sorted(r1["out"]) == sorted(r2["result"])

    def test_trace_relations_kwarg(self):
        session = CobraSession(make_orders_customer_db(100, 50),
                               CostCatalog(SLOW_REMOTE))

        @session.trace(name="P0", relations=[ORDERS_CUSTOMER_REL])
        def p0():
            result = []
            for o in load_all("orders"):
                cust = o.customer
                val = myFunc(o.o_id, cust.c_birth_year)
                result.append(val)
            return result

        assert p0.source.key() == make_p0().key()

    def test_builder_escape_hatch_still_works(self):
        session = CobraSession(make_wilos_db(100), CostCatalog(FAST_LOCAL))

        @session.trace(name="agg")
        def f(b):
            total = b.let("total", 0.0)
            with b.loop(b.load_all("tasks"), var="t") as t:
                b.let("total", total + t.t_hours)
            return total

        assert isinstance(f, Executable)
        assert f.run()["total"] > 0


# --------------------------------------------------------------------------
# List comprehensions: lowered onto the loop-accumulation path
# --------------------------------------------------------------------------

class TestListComprehensions:
    def _session(self):
        return CobraSession(make_wilos_db(200, ratio=10),
                            CostCatalog(FAST_LOCAL))

    def test_basic_comprehension_matches_explicit_loop(self):
        def comp():
            xs = [scale(t.t_hours) for t in load_all("tasks")]
            return xs

        def explicit():
            xs = []
            for t in load_all("tasks"):
                xs.append(scale(t.t_hours))
            return xs

        session = self._session()
        assert session.compile(lift_program(comp)).run().outputs["xs"] == \
            session.compile(lift_program(explicit)).run().outputs["xs"]

    def test_comprehension_with_filter(self):
        def comp():
            xs = [t.t_hours for t in load_all("tasks") if t.t_state == 2]
            return xs

        def explicit():
            xs = []
            for t in load_all("tasks"):
                if t.t_state == 2:
                    xs.append(t.t_hours)
            return xs

        session = self._session()
        got = session.compile(lift_program(comp)).run().outputs["xs"]
        assert got == session.compile(
            lift_program(explicit)).run().outputs["xs"]
        assert 0 < len(got) < 200

    def test_multiple_filters_nest(self):
        def comp():
            xs = [t.t_id for t in load_all("tasks")
                  if t.t_state == 2 if t.t_hours > 10]
            return xs

        session = self._session()
        out = session.compile(lift_program(comp)).run().outputs["xs"]
        exact = [r["t_id"] for r in session.db.table("tasks").to_rows()
                 if r["t_state"] == 2 and r["t_hours"] > 10]
        assert out == exact

    def test_comprehension_over_traced_collection_input(self):
        def comp(worklist=()):
            doubled = [wid + wid for wid in worklist]
            return doubled

        session = self._session()
        exe = session.compile(lift_program(comp))
        assert exe.run(worklist=[1, 5, 7]).outputs["doubled"] == [2, 10, 14]

    def test_comprehension_over_query_handle(self):
        def comp():
            ranked = [r.r_rank for r in q("roles").order_by("r_id")]
            return ranked

        session = self._session()
        out = session.compile(lift_program(comp)).run().outputs["ranked"]
        assert out == [r["r_rank"]
                       for r in session.db.table("roles").to_rows()]

    def test_returned_comprehension(self):
        def comp():
            return [t.t_hours for t in load_all("tasks")]

        session = self._session()
        out = session.compile(lift_program(comp)).run()
        assert len(out.outputs["_ret0"]) == 200

    def test_comprehension_variable_scoping(self):
        """The comprehension variable must not leak into (or clobber) the
        enclosing scope."""
        def comp():
            t = 7
            xs = [t.t_id for t in load_all("tasks")]
            n = t + 1          # the OUTER t, untouched by the comprehension
            return xs, n

        session = self._session()
        out = session.compile(lift_program(comp)).run()
        assert out.outputs["n"] == 8
        assert len(out.outputs["xs"]) == 200

    def test_nested_comprehension_rejected(self):
        def f():
            xs = [[y for y in load_all("roles")] for t in load_all("tasks")]
            return xs

        with pytest.raises(LiftError, match="nested"):
            lift_program(f)

    def test_multiple_for_clauses_rejected(self):
        def f():
            xs = [combine(t.t_id, r.r_id)
                  for t in load_all("tasks") for r in load_all("roles")]
            return xs

        with pytest.raises(LiftError, match="multiple `for`"):
            lift_program(f)

    def test_trace_time_source_rejected(self):
        def f():
            xs = [i + 1 for i in (1, 2, 3)]
            return xs

        with pytest.raises(LiftError, match="trace-time"):
            lift_program(f)

    def test_comprehension_in_while_guard_rejected(self):
        """The guard is re-evaluated every iteration by the interpreter,
        but a comprehension's accumulation loop would lower BEFORE the
        WhileRegion and freeze at entry — silently wrong results, so it
        must be a LiftError (the body is the right place for it)."""
        def f():
            n = 0
            total = 0
            while n < len([t.t_id for t in load_all("tasks")]):
                total = total + 1
                n = n + 10
            return total, n

        with pytest.raises(LiftError, match="while guard"):
            lift_program(f)

        # ...while a comprehension in the BODY (evaluated once per
        # iteration, like Python) stays liftable
        def ok():
            n = 0
            total = 0.0
            while n < 3:
                xs = [t.t_hours for t in load_all("tasks")]
                total = total + xs[0]
                n = n + 1
            return total

        session = self._session()
        out = session.compile(lift_program(ok)).run()
        first = session.db.table("tasks").to_rows()[0]["t_hours"]
        assert out.outputs["total"] == pytest.approx(3 * first)

    def test_genexp_rejected(self):
        def f_gen():
            xs = list(t.t_id for t in load_all("tasks"))
            return xs

        with pytest.raises(LiftError, match="generator expressions"):
            lift_program(f_gen)


# --------------------------------------------------------------------------
# Dict/set comprehensions: the same loop-accumulation path via MapPut
# --------------------------------------------------------------------------

class TestDictSetComprehensions:
    def _session(self):
        return CobraSession(make_wilos_db(200, ratio=10),
                            CostCatalog(FAST_LOCAL))

    def test_dict_comp_ir_byte_identical_to_explicit_loop(self):
        """``{k: v for ...}`` must lower to EXACTLY the IR of the explicit
        empty-map + m[k] = v loop (same accumulator name, same regions), so
        the optimizer sees one program shape for both spellings."""
        def comp():
            m = {t.t_id: scale(t.t_hours) for t in load_all("tasks")}
            return m

        def explicit():
            _comp0 = {}
            for t in load_all("tasks"):
                _comp0[t.t_id] = scale(t.t_hours)
            m = _comp0
            return m

        assert lift_program(comp, name="X").key() == \
            lift_program(explicit, name="X").key()

    def test_set_comp_ir_byte_identical_to_explicit_loop(self):
        """``{e for ...}`` is the keyed map with the member as its own key —
        byte-identical to the explicit ``m[e] = e`` loop."""
        def comp():
            s = {t.t_state for t in load_all("tasks")}
            return s

        def explicit():
            _comp0 = {}
            for t in load_all("tasks"):
                _comp0[t.t_state] = t.t_state
            s = _comp0
            return s

        assert lift_program(comp, name="X").key() == \
            lift_program(explicit, name="X").key()

    def test_dict_comp_runs(self):
        def comp():
            m = {t.t_id: t.t_hours for t in load_all("tasks")}
            return m

        session = self._session()
        out = session.compile(lift_program(comp)).run().outputs["m"]
        rows = session.db.table("tasks").to_rows()
        assert out == {r["t_id"]: r["t_hours"] for r in rows}

    def test_set_comp_dedups_and_filters(self):
        def comp():
            s = {t.t_state for t in load_all("tasks") if t.t_hours > 10}
            return s

        session = self._session()
        out = session.compile(lift_program(comp)).run().outputs["s"]
        rows = session.db.table("tasks").to_rows()
        want = {r["t_state"] for r in rows if r["t_hours"] > 10}
        assert set(out) == want
        assert all(k == v for k, v in out.items())

    def test_dict_comp_with_filter_matches_explicit(self):
        def comp():
            m = {t.t_id: t.t_hours for t in load_all("tasks")
                 if t.t_state == 2}
            return m

        def explicit():
            m = {}
            for t in load_all("tasks"):
                if t.t_state == 2:
                    m[t.t_id] = t.t_hours
            return m

        session = self._session()
        got = session.compile(lift_program(comp)).run().outputs["m"]
        assert got == session.compile(
            lift_program(explicit)).run().outputs["m"]
        assert 0 < len(got) < 200

    def test_nested_dict_comp_rejected(self):
        def f():
            m = {t.t_id: [r.r_id for r in load_all("roles")]
                 for t in load_all("tasks")}
            return m

        with pytest.raises(LiftError, match="nested"):
            lift_program(f)


# --------------------------------------------------------------------------
# SCAN: while/early-exit end to end (issue acceptance)
# --------------------------------------------------------------------------

class TestScanEndToEnd:
    @pytest.fixture(scope="class")
    def compiled(self):
        db = make_wilos_db(2000)
        session = CobraSession(db, CostCatalog(SLOW_REMOTE))
        return db, session, session.compile(make_scan())

    def test_rewrite_win_in_plan_report(self, compiled):
        _, session, exe = compiled
        # the aggregation inside the while body moved into SQL ...
        assert "scalarQuery" in repr(exe.program.body)
        assert "scalarQuery" not in repr(exe.source.body)
        # ... because the search found a cheaper alternative
        rep = exe.report
        assert rep.alternatives >= 2 and rep.est_cost_s > 0
        baseline = session.execute(exe.source, threshold=1e9)
        optimized = exe.run(threshold=1e9)
        assert optimized.simulated_s < baseline.simulated_s

    def test_while_survives_rewriting(self, compiled):
        _, _, exe = compiled
        assert isinstance(exe.source.body.parts[2], WhileRegion)
        rewritten = [r for r in exe.program.body.parts
                     if isinstance(r, WhileRegion)]
        assert len(rewritten) == 1

    def test_run_matches_baseline_per_threshold(self, compiled):
        _, session, exe = compiled
        for th in (50.0, 2e4, 1e9):
            base = session.execute(exe.source, threshold=th)
            for mode in ("fast", "exact"):
                out = session.execute(exe.program, mode=mode, threshold=th)
                assert out["state"] == base["state"]
                assert out["total"] == pytest.approx(base["total"], rel=1e-4)

    def test_run_batch_respects_per_invocation_early_exit(self, compiled):
        _, _, exe = compiled
        sets = [{"threshold": 50.0}, {"threshold": 2e4}, {"threshold": 1e9},
                {"threshold": 50.0}]
        batch = exe.run_batch(sets)
        assert batch.batched
        states = [r.outputs["state"] for r in batch.results]
        assert states[0] == states[3]
        assert len(set(states[:3])) == 3  # three different stop rounds
        for ps, r in zip(sets, batch.results):
            assert exe.run(**ps).outputs == r.outputs

    def test_interpreter_equivalence_before_vs_after_rewrite(self, compiled):
        """The optimized while/break program computes the same state as the
        source under BOTH interpreter modes (rewrite ∘ early-exit safety)."""
        db, session, exe = compiled
        envs = {}
        for prog, tag in ((exe.source, "src"), (exe.program, "opt")):
            for mode in ("exact", "fast"):
                envs[(tag, mode)] = session.execute(
                    prog, mode=mode, threshold=2e4)
        ref = envs[("src", "exact")]
        for k, out in envs.items():
            assert out["state"] == ref["state"], k
            assert out["total"] == pytest.approx(ref["total"], rel=1e-4), k


# --------------------------------------------------------------------------
# Round trip: builder program -> plain-Python rendering -> lift
# --------------------------------------------------------------------------
# A spec draws a small imperative program; _spec_to_builder emits it through
# ProgramBuilder, _spec_to_source renders the equivalent plain Python, and
# lifting the rendering must reproduce the builder's IR byte for byte.

_SPEC_COLS = {"tasks": ("t_hours", "t_state", "t_role_id"),
              "roles": ("r_rank", "r_id")}


def _spec_to_source(spec) -> str:
    lines = ["def GEN():"]
    emit = lines.append
    names = []
    for i, (kind, c, k, guard) in enumerate(spec["stmts"]):
        if kind == "acc":
            emit(f"    acc{i} = 0.0")
            names.append(f"acc{i}")
        elif kind == "add":
            emit(f"    lst{i} = []")
            names.append(f"lst{i}")
        else:
            emit(f"    map{i} = {{}}")
            names.append(f"map{i}")
    emit(f"    for t0 in load_all({spec['table']!r}):")
    for i, (kind, c, k, guard) in enumerate(spec["stmts"]):
        pad = "        "
        if guard is not None:
            emit(f"{pad}if t0.{guard} > {k}:")
            pad += "    "
        if kind == "acc":
            emit(f"{pad}acc{i} = acc{i} + t0.{c} * {k}")
        elif kind == "add":
            emit(f"{pad}lst{i}.append(t0.{c} + {k})")
        else:
            emit(f"{pad}map{i}[t0.{c}] = {k}")
    if spec["use_while"]:
        emit("    w = 0")
        emit(f"    while w < {spec['while_iters']}:")
        emit("        w = w + 1")
        if spec["brk"]:
            emit(f"        if w >= {spec['brk_at']}:")
            emit("            break")
        names.append("w")
    emit("    return " + ", ".join(names))
    return "\n".join(lines) + "\n"


def _spec_to_builder(spec) -> Program:
    b = ProgramBuilder("GEN")
    names = []
    for i, (kind, c, k, guard) in enumerate(spec["stmts"]):
        if kind == "acc":
            names.append(b.let(f"acc{i}", 0.0))
        elif kind == "add":
            names.append(b.let(f"lst{i}", b.empty_list()))
        else:
            names.append(b.let(f"map{i}", b.empty_map()))
    with b.loop(b.load_all(spec["table"]), var="t0") as t0:
        for i, (kind, c, k, guard) in enumerate(spec["stmts"]):
            def emit_one(i=i, kind=kind, c=c, k=k):
                if kind == "acc":
                    b.let(f"acc{i}", b.var(f"acc{i}") + getattr(t0, c) * k)
                elif kind == "add":
                    b.add(f"lst{i}", getattr(t0, c) + k)
                else:
                    b.put(f"map{i}", getattr(t0, c), k)
            if guard is not None:
                with b.when(getattr(t0, guard) > k):
                    emit_one()
            else:
                emit_one()
    if spec["use_while"]:
        w = b.let("w", 0)
        with b.while_(w < spec["while_iters"]):
            b.let("w", w + 1)
            if spec["brk"]:
                with b.when(w >= spec["brk_at"]):
                    b.brk()
        names.append(w)
    return b.build(outputs=names)


def _round_trip(spec):
    expected = _spec_to_builder(spec)
    lifted = lift_source(_spec_to_source(spec),
                         env={"load_all": load_all}, name="GEN")
    assert lifted.key() == expected.key()
    assert program_fingerprint(lifted) == program_fingerprint(expected)


_FIXED_SPECS = [
    {"table": "tasks", "stmts": [("acc", "t_hours", 2, None)],
     "use_while": False, "while_iters": 0, "brk": False, "brk_at": 0},
    {"table": "tasks",
     "stmts": [("acc", "t_hours", 3, "t_state"), ("add", "t_role_id", 1, None)],
     "use_while": True, "while_iters": 3, "brk": True, "brk_at": 2},
    {"table": "roles",
     "stmts": [("mapput", "r_id", 4, None), ("acc", "r_rank", 1, "r_id")],
     "use_while": True, "while_iters": 2, "brk": False, "brk_at": 1},
]


class TestRoundTripFixed:
    @pytest.mark.parametrize("spec", _FIXED_SPECS,
                             ids=[f"spec{i}" for i in range(len(_FIXED_SPECS))])
    def test_fixed_specs_round_trip(self, spec):
        _round_trip(spec)


if HAS_HYPOTHESIS:
    @st.composite
    def program_spec(draw):
        table = draw(st.sampled_from(sorted(_SPEC_COLS)))
        cols = _SPEC_COLS[table]
        n = draw(st.integers(1, 3))
        stmts = []
        for _ in range(n):
            kind = draw(st.sampled_from(["acc", "add", "mapput"]))
            c = draw(st.sampled_from(cols))
            k = draw(st.integers(1, 9))
            guard = draw(st.one_of(st.none(), st.sampled_from(cols)))
            stmts.append((kind, c, k, guard))
        use_while = draw(st.booleans())
        while_iters = draw(st.integers(1, 4))
        brk = draw(st.booleans())
        brk_at = draw(st.integers(1, while_iters))
        return {"table": table, "stmts": stmts, "use_while": use_while,
                "while_iters": while_iters, "brk": brk, "brk_at": brk_at}

    class TestRoundTripProperty:
        @settings(max_examples=60, deadline=None)
        @given(spec=program_spec())
        def test_generated_program_round_trips(self, spec):
            _round_trip(spec)
else:
    @pytest.mark.skip(reason="optional dev dependency "
                             "(pip install hypothesis)")
    def test_generated_program_round_trips():
        pass


# --------------------------------------------------------------------------
# Rewriting stays conservative around early exits
# --------------------------------------------------------------------------

class TestConservativeRewrites:
    def test_loop_with_break_is_not_extracted_to_sql(self):
        def f(cap=10):
            n = 0
            for t in load_all("tasks"):
                n = n + 1
                if n >= cap:
                    break
            return n

        session = CobraSession(make_wilos_db(500), CostCatalog(SLOW_REMOTE))
        exe = session.compile(lift_program(f))
        # the loop must stay imperative: no aggregate extraction is sound
        # when iteration may stop early
        assert "scalarQuery" not in repr(exe.program.body)
        assert exe.run(cap=7)["n"] == 7
        assert exe.run(cap=10**9)["n"] == 500

    def test_vectorized_mode_falls_back_on_break(self):
        def f(cap=3):
            out = []
            for t in load_all("tasks"):
                out.append(t.t_hours)
                if t.t_state == 0:
                    break
            return out

        p = lift_program(f)
        session = CobraSession(make_wilos_db(300), CostCatalog(FAST_LOCAL))
        fast = session.execute(p)
        exact = session.execute(p, mode="exact")
        assert fast.outputs == exact.outputs
        assert fast.simulated_s == pytest.approx(exact.simulated_s)


# --------------------------------------------------------------------------
# Subscript reads on traced values (IIndex)
# --------------------------------------------------------------------------

class TestSubscriptReads:
    def _session(self, network=FAST_LOCAL):
        return CobraSession(make_wilos_db(60, ratio=10), CostCatalog(network))

    def test_traced_list_index(self):
        def f():
            xs = []
            for t in load_all("tasks"):
                xs.append(t.t_hours)
            first = xs[0]
            return first

        exe = self._session().compile(lift_program(f))
        assert exe.run()["first"] == exe.run_baseline()["first"]

    def test_traced_map_read(self):
        def f(key=3):
            m = {}
            for t in load_all("tasks"):
                m[t.t_id] = t.t_hours
            v = m[key]
            return v

        exe = self._session().compile(lift_program(f))
        assert exe.run(key=5)["v"] == exe.run_baseline(key=5)["v"]

    def test_input_collection_index(self):
        def f(worklist=()):
            w0 = worklist[0]
            return w0

        exe = self._session().compile(lift_program(f))
        assert exe.run(worklist=[42, 7])["w0"] == 42

    def test_query_result_row_index(self):
        def f():
            rows = load_all("roles")
            first = rows[0]
            return first

        exe = self._session().compile(lift_program(f))
        row = exe.run()["first"]
        assert row["r_id"] == 0 and "r_rank" in row

    def test_index_inside_loop_body_fast_equals_exact(self):
        """IIndex in a loop body is outside the vectorizable subset; the
        fast interpreter must fall back and match exact execution."""
        def f(offsets=()):
            out = []
            for t in load_all("tasks"):
                out.append(t.t_hours + offsets[0])
            return out

        p = lift_program(f)
        session = self._session()
        fast = session.execute(p, offsets=[10.0])
        exact = session.execute(p, mode="exact", offsets=[10.0])
        assert fast.outputs == exact.outputs

    def test_fingerprint_distinguishes_index(self):
        def fa(worklist=()):
            x = worklist[0]
            return x

        def fb(worklist=()):
            x = worklist[1]
            return x

        assert program_fingerprint(lift_program(fa, name="F")) != \
            program_fingerprint(lift_program(fb, name="F"))

    def test_builder_getitem_emits_iindex(self):
        from repro.core.regions import IIndex
        b = ProgramBuilder("X")
        w = b.input("w", ())
        e = w[0]
        assert isinstance(e.ir, IIndex)
        assert e.ir.key()[0] == "iindex"

    def test_slice_rejected(self):
        def f(worklist=()):
            x = worklist[0:2]
            return x

        with pytest.raises(LiftError, match="slice"):
            lift_program(f)

    def test_trace_time_subscript_still_static(self):
        tables = ("tasks", "roles")

        def f():
            n = 0
            for t in load_all(tables[0]):
                n = n + 1
            return n

        exe = self._session().compile(lift_program(f))
        assert exe.run()["n"] == 60
