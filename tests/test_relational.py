"""Relational substrate: tables, algebra execution, estimates, client env."""

import numpy as np
import pytest

from repro.relational import (AggSpec, Aggregate, ClientEnv, Cmp, Col,
                              DatabaseServer, FAST_LOCAL, Field, Join, Lit,
                              OrderBy, Project, Scan, Schema, Select,
                              SLOW_REMOTE, Table, equi_join_indices)


@pytest.fixture
def db():
    rng = np.random.default_rng(0)
    cust = Table.from_columns(
        "customer",
        Schema.of(Field("c_id", "int64", 8), Field("c_year", "int32", 4),
                  Field("c_pay", "int32", 120)),
        c_id=np.arange(100), c_year=rng.integers(1940, 2000, 100),
        c_pay=rng.integers(0, 10, 100))
    orders = Table.from_columns(
        "orders",
        Schema.of(Field("o_id", "int64", 8), Field("o_cid", "int64", 8),
                  Field("o_amt", "float64", 8)),
        o_id=np.arange(500), o_cid=rng.integers(0, 100, 500),
        o_amt=rng.uniform(0, 1000, 500))
    return DatabaseServer({"customer": cust, "orders": orders})


def test_row_bytes_uses_wire_sizes(db):
    assert db.table("customer").row_bytes == 8 + 4 + 120


def test_select_matches_numpy(db):
    t = Select(Cmp("<", Col("c_year"), Lit(1960)), Scan("customer")).execute(db)
    want = int((np.asarray(db.table("customer").column("c_year")) < 1960).sum())
    assert t.nrows == want


def test_join_row_count_and_order(db):
    res = Join(Scan("orders"), Scan("customer"), "o_cid", "c_id").execute(db)
    assert res.nrows == 500  # FK integrity: every order matches one customer
    # left-major order preserved
    assert np.array_equal(np.asarray(res.column("o_id")), np.arange(500))


def test_equi_join_indices_all_pairs():
    lk = np.array([1, 2, 2, 3])
    rk = np.array([2, 2, 3, 9])
    li, ri = equi_join_indices(lk, rk)
    pairs = set(zip(li.tolist(), ri.tolist()))
    assert pairs == {(1, 0), (1, 1), (2, 0), (2, 1), (3, 2)}


def test_groupby_sum_matches_numpy(db):
    res = Aggregate(("o_cid",), (AggSpec("sum", "o_amt", "s"),
                                 AggSpec("count", None, "n")),
                    Scan("orders")).execute(db)
    a = np.asarray(db.table("orders").column("o_cid"))
    b = np.asarray(db.table("orders").column("o_amt"))
    for k, s, n in zip(np.asarray(res.column("o_cid")),
                       np.asarray(res.column("s")),
                       np.asarray(res.column("n"))):
        sel = b[a == k]
        assert abs(float(s) - sel.sum()) < 1e-2 * max(1.0, abs(sel.sum()))
        assert int(n) == len(sel)


def test_orderby_sorted(db):
    res = OrderBy(("c_year",), Scan("customer")).execute(db)
    ys = np.asarray(res.column("c_year"))
    assert np.all(ys[:-1] <= ys[1:])


def test_estimates_reasonable(db):
    est = db.estimate(Scan("orders"))
    assert est.n_rows == 500
    est = db.estimate(Select(Cmp("==", Col("o_cid"), Lit(5)), Scan("orders")))
    assert 1 <= est.n_rows <= 20  # 500/NDV(100) = 5
    est = db.estimate(Join(Scan("orders"), Scan("customer"), "o_cid", "c_id"))
    assert 250 <= est.n_rows <= 1000


def test_client_env_charges_query_cost(db):
    env = ClientEnv(db, SLOW_REMOTE)
    t = env.execute_query(Scan("customer"))
    expected_transfer = t.nrows * t.row_bytes / SLOW_REMOTE.bandwidth_bytes_per_s
    assert env.clock >= SLOW_REMOTE.rtt_s + expected_transfer
    assert env.n_queries == 1


def test_orm_cache_hit_is_local(db):
    env = ClientEnv(db, SLOW_REMOTE)
    env.point_lookup("customer", "c_id", 7)
    q1, t1 = env.n_queries, env.clock
    env.point_lookup("customer", "c_id", 7)
    assert env.n_queries == q1            # cache hit: no extra round trip
    assert env.clock - t1 < 1e-6


def test_prefetch_cache_lookup(db):
    env = ClientEnv(db, FAST_LOCAL)
    env.cache_by_column(db.table("customer"), "c_id")
    row = env.lookup_cache("customer", "c_id", 42)
    assert row["c_id"] == 42
    assert env.lookup_cache("customer", "c_id", 10**9) is None


def test_project_computed_column(db):
    from repro.relational import Arith
    q = Project(("o_id",), Scan("orders"), computed=(("dbl", Arith("*", Col("o_amt"), Lit(2.0))),))
    t = q.execute(db)
    assert np.allclose(np.asarray(t.column("dbl")),
                       2 * np.asarray(db.table("orders").column("o_amt")), rtol=1e-5)


def test_table_semantic_equality(db):
    t = db.table("customer")
    shuffled = t.take(np.random.default_rng(3).permutation(t.nrows))
    assert t.same_rows(shuffled)
    assert not t.same_rows(shuffled, ordered=True) or t.nrows <= 1
