"""ExecutionContext-aware optimizer surface: RuleSet, CostModel, context.

Issue acceptance:
  * the memo search selects a DIFFERENT winning plan for
    ``ExecutionContext(batch_size=1)`` vs ``batch_size=64`` on a paper
    program (W_E, Fig. 14 pattern E; also the while/early-exit SCAN);
  * a user-defined rule registered via the public ``RuleSet`` API fires
    and wins a plan without modifying ``core/rules.py``;
  * observed iteration counts in the context's ``StatsProfile`` (not
    ``while_iters_default``) change which alternative wins;
  * plan-cache / plan-store keys carry the context fingerprint;
  * ``OptimizerConfig.cost_model`` plugs a user CostModel subclass into
    the search.
"""

import dataclasses

import pytest

from repro.api import (CobraSession, CostModel, ExecutionContext,
                       OptimizerConfig, RuleSet, StatsProfile, add_slot_variant,
                       cobra_rule, program_sites, slot_view)
from repro.core import (CostCatalog, LoopRegion, WhileRegion, loop_site_key,
                        while_site_key)
from repro.core.cost import query_has_params
from repro.programs import (make_orders_customer_db, make_p0, make_scan,
                            make_wilos_db, make_wilos_e)
from repro.relational.algebra import Cmp, Col, Param, Scan, Select
from repro.relational.database import SLOW_REMOTE
from repro.runtime import ServingRuntime


def wilos_session(n_tasks=300, **kw):
    return CobraSession(make_wilos_db(n_tasks, ratio=10),
                        CostCatalog(SLOW_REMOTE),
                        config=OptimizerConfig.preset("paper-exp1-3"), **kw)


def find_region(program, kind):
    def walk(r):
        if isinstance(r, kind):
            return r
        for c in r.children():
            found = walk(c)
            if found is not None:
                return found
    return walk(program.body)


def scan_while_site():
    return while_site_key(find_region(make_scan(), WhileRegion).pred)


def we_loop_site():
    lp = find_region(make_wilos_e(), LoopRegion)
    return loop_site_key(lp.var, lp.source)


def plan_kind(exe_or_result):
    program = getattr(exe_or_result, "program", exe_or_result)
    body = repr(program.body)
    return "prefetch" if "prefetch" in body else \
        "join" if "JOIN" in body else "query"


# --------------------------------------------------------------------------
# Acceptance: batch size flips the winning plan
# --------------------------------------------------------------------------

class TestBatchSizeFlipsPlan:
    def test_wilos_e_flips_between_one_shot_and_batch64(self):
        """Pattern E with a short observed worklist: at batch_size=1 the
        correlated per-key σ wins (one small fetch beats pulling all of
        ``tasks``); at batch_size=64 the prefetch site — identical for every
        invocation, so fetched once per batch — amortizes to C_Q/64 and
        wins. Same program, same statistics, same rules: only the
        ExecutionContext differs."""
        stats = StatsProfile.of({we_loop_site(): 1.0})
        session = wilos_session()
        one = session.compile(make_wilos_e(),
                              context=ExecutionContext(batch_size=1,
                                                       stats=stats))
        big = session.compile(make_wilos_e(),
                              context=ExecutionContext(batch_size=64,
                                                       stats=stats))
        assert plan_kind(one) == "query"
        assert plan_kind(big) == "prefetch"
        assert one.program.body.key() != big.program.body.key()
        # both plans compute identical results
        r1 = one.run(worklist=[1, 3])
        r2 = big.run(worklist=[1, 3])
        assert r1.outputs == r2.outputs

    def test_scan_flips_inside_while_body(self):
        """SCAN's while body never hoists prefetches across the guard, so at
        batch_size=1 the T5 correlated aggregate (one round trip per
        iteration) wins; batched, the binding-free prefetch site inside the
        body is fetched once per BATCH (shared site cache) and wins."""
        session = wilos_session()
        one = session.compile(make_scan(), context=ExecutionContext())
        big = session.compile(make_scan(),
                              context=ExecutionContext(batch_size=64))
        assert plan_kind(one) == "query"
        assert plan_kind(big) == "prefetch"
        assert big.est_cost_s < one.est_cost_s

    def test_batched_cost_is_cheaper_never_pricier(self):
        session = wilos_session()
        costs = [session.compile(make_scan(),
                                 context=ExecutionContext(batch_size=b)
                                 ).est_cost_s
                 for b in (1, 8, 64)]
        assert costs[0] > costs[1] > costs[2]

    def test_p0_winner_stable_across_batch_sizes(self):
        """P0's alternatives (N+1 / join / prefetch) are all binding-free,
        so batching amortizes them equally — the winner must NOT flip."""
        db = make_orders_customer_db(100, 5000)
        session = CobraSession(db, CostCatalog(SLOW_REMOTE),
                               config=OptimizerConfig.preset("paper-exp1-3"))
        kinds = {plan_kind(session.compile(
            make_p0(), context=ExecutionContext(batch_size=b)))
            for b in (1, 64)}
        assert len(kinds) == 1


# --------------------------------------------------------------------------
# Acceptance: observed iteration counts change the winner
# --------------------------------------------------------------------------

class TestObservedIterations:
    def test_observed_while_iters_flip_winner_at_fixed_batch(self):
        """At batch_size=2, a short-lived while (observed 1 iteration)
        keeps the per-iteration aggregate query; a long-lived one (observed
        16) makes the once-per-batch prefetch win. The catalog default
        (while_iters_default=8) never moves — only the observation does."""
        session = wilos_session()
        site = scan_while_site()
        short = session.compile(make_scan(), context=ExecutionContext(
            batch_size=2, stats=StatsProfile.of({site: 1.0})))
        long_ = session.compile(make_scan(), context=ExecutionContext(
            batch_size=2, stats=StatsProfile.of({site: 16.0})))
        assert plan_kind(short) == "query"
        assert plan_kind(long_) == "prefetch"

    def test_observed_loop_iters_scale_cost(self):
        """W_E's worklist loop has no table statistics behind it; observed
        lengths replace loop_iters_default in the estimate."""
        session = wilos_session()
        site = we_loop_site()
        est = {}
        for n in (1.0, 100.0):
            exe = session.compile(make_wilos_e(), context=ExecutionContext(
                stats=StatsProfile.of({site: n})))
            est[n] = exe.est_cost_s
        assert est[100.0] > est[1.0]

    def test_unobserved_site_uses_catalog_default(self):
        session = wilos_session()
        default = session.compile(make_scan())
        other = session.compile(make_scan(), context=ExecutionContext(
            stats=StatsProfile.of({"while:unrelated0000": 1000.0})))
        assert default.est_cost_s == other.est_cost_s
        # ...and the unrelated observation does not even change the cache
        # key: the fingerprint is restricted to the program's own sites
        assert other.from_cache


# --------------------------------------------------------------------------
# Acceptance: observed binding diversity amortizes parameterized sites
# --------------------------------------------------------------------------

class TestBindingDiversity:
    def _tasks_group(self):
        from repro.core import param_group_key
        return param_group_key(("tasks",))

    def test_observed_diversity_flips_we_plan(self):
        """THE binding-diversity flip (issue acceptance): at batch_size=64
        with a short observed worklist, the binding-free prefetch wins under
        the 0/1 rule (parameterized σ never amortizes) — but an observed
        distinct-binding fraction of 1/64 (every invocation reuses the same
        worklist key, so the site cache serves 63 of 64 fetches) amortizes
        the σ site to C_Q/64 and the query plan wins instead. Same program,
        same statistics, same batch size: only the observed diversity
        differs."""
        session = wilos_session()
        iters = {we_loop_site(): 1.0}
        base = session.compile(make_wilos_e(), context=ExecutionContext(
            batch_size=64, stats=StatsProfile.of(iters)))
        amortized = session.compile(make_wilos_e(), context=ExecutionContext(
            batch_size=64, stats=StatsProfile.of(
                iters, bindings={self._tasks_group(): 1.0 / 64})))
        assert plan_kind(base) == "prefetch"
        assert plan_kind(amortized) == "query"
        assert amortized.est_cost_s < base.est_cost_s
        # both compute identical results
        assert base.run(worklist=[1, 3]).outputs == \
            amortized.run(worklist=[1, 3]).outputs

    def test_high_diversity_keeps_unamortized_winner(self):
        """Fully diverse bindings (d=1.0) must price like no sharing at
        all — the conservative default."""
        session = wilos_session()
        iters = {we_loop_site(): 1.0}
        none = session.compile(make_wilos_e(), context=ExecutionContext(
            batch_size=64, stats=StatsProfile.of(iters)))
        diverse = session.compile(make_wilos_e(), context=ExecutionContext(
            batch_size=64, stats=StatsProfile.of(
                iters, bindings={self._tasks_group(): 1.0})))
        assert plan_kind(diverse) == plan_kind(none) == "prefetch"
        assert diverse.est_cost_s == none.est_cost_s

    def test_param_site_amortization_floor_and_default(self):
        cm = CostModel(wilos_session().db, CostCatalog(SLOW_REMOTE),
                       ExecutionContext(batch_size=8, stats=StatsProfile.of(
                           bindings={self._tasks_group(): 0.01})))
        param_q = Select(Cmp("==", Col("t_role_id"), Param("r")),
                         Scan("tasks"))
        # observed 0.01 floors at 1/B (at most one fetch per distinct
        # binding, and at least one per batch)
        assert cm.param_site_amortization(param_q) == pytest.approx(1 / 8)
        # unobserved group: no amortization
        other = Select(Cmp("==", Col("r_rank"), Param("r")), Scan("roles"))
        assert cm.param_site_amortization(other) == 1.0
        # one-shot context: batching cannot help
        cm1 = CostModel(wilos_session().db, CostCatalog(SLOW_REMOTE),
                        ExecutionContext(batch_size=1, stats=StatsProfile.of(
                            bindings={self._tasks_group(): 0.01})))
        assert cm1.param_site_amortization(param_q) == 1.0

    def test_unrelated_binding_site_leaves_plans_hot(self):
        """A published diversity for a group the program doesn't contain
        never invalidates its plans (fingerprint restriction)."""
        from repro.core import param_group_key
        db = make_orders_customer_db(100, 5000)
        session = CobraSession(db, CostCatalog(SLOW_REMOTE),
                               config=OptimizerConfig.preset("paper-exp1-3"))
        session.compile(make_p0())
        again = session.compile(make_p0(), context=ExecutionContext(
            stats=StatsProfile.of(
                bindings={param_group_key(("tasks",)): 0.1})))
        assert again.from_cache

    def test_program_param_sites(self):
        from repro.api import program_param_sites
        assert program_param_sites(make_wilos_e()) == (self._tasks_group(),)
        assert self._tasks_group() in program_param_sites(make_scan())
        assert program_param_sites(make_p0()) == ()      # binding-free

    def test_report_carries_binding_diversity(self):
        session = wilos_session()
        exe = session.compile(make_wilos_e(), context=ExecutionContext(
            batch_size=64, stats=StatsProfile.of(
                bindings={self._tasks_group(): 0.25})))
        assert exe.report.binding_diversity == {self._tasks_group(): 0.25}
        assert "binding-diversity~0.25" in exe.report.describe()

    def test_serving_loop_flips_both_ways_end_to_end(self):
        """The full closed loop (issue acceptance): serve W_E at
        batch_size=8. Registration (no observations) picks the prefetch
        plan. A phase of IDENTICAL worklists publishes iters=1 and
        d=1/8 -> the σ plan wins the context recompile (with iters alone
        prefetch would still win: the flip is diversity-driven). A phase
        of fully DIVERSE worklists pushes the published mean back up ->
        the prefetch plan returns. Every response stays bit-identical to
        uncached execution."""
        session = wilos_session()
        rt = ServingRuntime(session, batch_size=8, drift_threshold=1e9)
        rt.register(make_wilos_e())
        assert plan_kind(rt.executable("W_E")) == "prefetch"

        identical = [("W_E", {"worklist": [1]})] * 16
        responses = rt.serve(identical)
        assert plan_kind(rt.executable("W_E")) == "query"   # flip #1
        assert rt.context_recompiles >= 1
        # iters alone (no diversity) would NOT have flipped at batch 8:
        iters_only = session.compile(make_wilos_e(), context=ExecutionContext(
            batch_size=8, stats=StatsProfile.of({we_loop_site(): 1.0})))
        assert plan_kind(iters_only) == "prefetch"
        published = rt.feedback.telemetry()["binding_sites"]
        assert published[self._tasks_group()]["published"] == \
            pytest.approx(1 / 8)

        diverse = [("W_E", {"worklist": [i % 20]}) for i in range(16)]
        responses += rt.serve(diverse)
        assert plan_kind(rt.executable("W_E")) == "prefetch"  # flip #2
        # bit-identical to uncached execution, throughout both phases
        for (name, params), r in zip(identical + diverse, responses):
            assert r.outputs == session.execute(make_wilos_e(),
                                                **params).outputs


# --------------------------------------------------------------------------
# Context in plan identity
# --------------------------------------------------------------------------

class TestContextKeys:
    def test_program_sites_finds_while_and_collection_loops(self):
        assert scan_while_site() in program_sites(make_scan())
        assert we_loop_site() in program_sites(make_wilos_e())
        assert program_sites(make_p0()) == ()  # query-source loop only

    def test_distinct_batch_sizes_distinct_cache_entries(self):
        session = wilos_session()
        a = session.compile(make_scan(), context=ExecutionContext(batch_size=1))
        b = session.compile(make_scan(), context=ExecutionContext(batch_size=64))
        assert not a.from_cache and not b.from_cache
        assert session.memo_runs == 2
        # repeat compiles under each context hit their own entries
        assert session.compile(make_scan(),
                               context=ExecutionContext(batch_size=1)).from_cache
        assert session.compile(make_scan(),
                               context=ExecutionContext(batch_size=64)).from_cache

    def test_plan_store_keeps_contexts_apart(self, tmp_path):
        session = wilos_session(plan_store=str(tmp_path / "plans"))
        session.compile(make_scan(), context=ExecutionContext(batch_size=1))
        session.compile(make_scan(), context=ExecutionContext(batch_size=64))
        assert len(session.plan_store) == 2
        # a second session warm-starts per context from disk
        warm = wilos_session(plan_store=str(tmp_path / "plans"))
        hit = warm.compile(make_scan(), context=ExecutionContext(batch_size=64))
        assert hit.from_cache and plan_kind(hit) == "prefetch"

    def test_report_carries_context_fingerprint(self):
        session = wilos_session()
        exe = session.compile(make_scan(),
                              context=ExecutionContext(batch_size=64))
        assert exe.report.context_fp[1] == 64
        assert "batch=64" in exe.report.describe()

    def test_context_validation(self):
        with pytest.raises(ValueError):
            ExecutionContext(batch_size=0)


# --------------------------------------------------------------------------
# Acceptance: user rules via the public RuleSet API
# --------------------------------------------------------------------------

class TestRuleSet:
    def _limit_rule(self):
        """A user transformation: rewrite a binding-free fold-over-Scan
        source into a fold over LIMIT(n) of it — sound only under
        application-specific knowledge (the program consumes at most n
        rows), which is exactly why it belongs in user space, not core."""
        from repro.core.fir import FFoldE, FQueryE
        from repro.relational.algebra import Limit

        @cobra_rule("user-limit", match="slot-project",
                    doc="fold over Scan(R) -> fold over LIMIT 3 of it")
        def user_limit(memo, and_id, ctx):
            s = slot_view(memo, and_id)
            if s is None or s.prefetches:
                return 0
            fold = s.fold
            if not (isinstance(fold.source, FQueryE)
                    and isinstance(fold.source.query, Scan)):
                return 0
            new_fold = FFoldE(fold.func, fold.init,
                              FQueryE(Limit(3, fold.source.query)),
                              fold.acc_names, fold.row_name)
            return add_slot_variant(memo, and_id, s.var, s.index, new_fold,
                                    ctx, fold)

        return user_limit

    def test_user_rule_fires_and_wins_without_touching_core(self):
        """Acceptance: the rule registered through the public API produces
        the winning plan — a LIMIT appears in the compiled program, which no
        built-in rule can emit."""
        from repro.programs import make_wilos_b
        rules = RuleSet.default().with_rule(self._limit_rule())
        session = CobraSession(
            make_wilos_db(300, ratio=10), CostCatalog(SLOW_REMOTE),
            config=OptimizerConfig(exclude_rules=("T3",), rule_set=rules))
        exe = session.compile(make_wilos_b())
        assert "LIMIT 3" in repr(exe.program.body)
        baseline = CobraSession(
            make_wilos_db(300, ratio=10), CostCatalog(SLOW_REMOTE),
            config=OptimizerConfig.preset("paper-exp1-3")).compile(
                make_wilos_b())
        assert exe.est_cost_s < baseline.est_cost_s

    def test_rule_identity_in_cache_key(self):
        """Two configs differing only in a registered user rule must not
        share plan-cache entries."""
        rules = RuleSet.default().with_rule(self._limit_rule())
        base = OptimizerConfig.preset("paper-exp1-3")
        custom = dataclasses.replace(base, rule_set=rules)
        assert base.cache_key() != custom.cache_key()
        assert ("user-limit" in [fp[0] for fp in custom._rules_key()])

    def test_ruleset_registry_operations(self):
        rs = RuleSet.default()
        assert "T5" in rs and "toFIR" in rs.names()
        assert len(rs.without("T5")) == len(rs) - 1
        sub = rs.subset("toFIR", "T5")
        assert sub.names() == ("toFIR", "T5")
        with pytest.raises(KeyError):
            rs.subset("nope")
        with pytest.raises(KeyError):
            rs.rule("nope")
        # decorator registration form
        @rs.register(name="noop-rule", match="loop")
        def noop_rule(memo, and_id, ctx):
            return 0
        assert "noop-rule" in rs and rs.rule("noop-rule").revision != "builtin"
        assert "noop-rule" in rs.describe()

    def test_default_is_a_fresh_copy(self):
        a = RuleSet.default()
        b = RuleSet.default()
        a.register(self._limit_rule())
        assert "user-limit" in a and "user-limit" not in b

    def test_config_rejects_non_ruleset(self):
        cfg = OptimizerConfig(rule_set="not a ruleset")
        with pytest.raises(TypeError):
            cfg.resolve_rules()

    def test_config_name_filters_within_custom_set(self):
        rules = RuleSet.default().with_rule(self._limit_rule())
        cfg = OptimizerConfig(rule_set=rules, exclude_rules=("user-limit",))
        assert "user-limit" not in cfg.rule_names()
        cfg2 = OptimizerConfig(rule_set=rules, rules=("toFIR", "user-limit"))
        assert cfg2.rule_names() == ("toFIR", "user-limit")


# --------------------------------------------------------------------------
# Satellite: declared before=/after= ordering constraints on rules
# --------------------------------------------------------------------------

class TestRuleOrdering:
    def _noop(self, name, **kw):
        @cobra_rule(name, match="loop", **kw)
        def fn(memo, and_id, ctx):
            return 0
        return fn

    def test_before_reorders_against_registry_order(self):
        rs = RuleSet()
        rs.register(self._noop("b"))
        rs.register(self._noop("a", before=("b",)))
        assert [r.name for r in rs.rules()] == ["b", "a"]      # registry
        assert [r.name for r in rs.resolve()] == ["a", "b"]    # resolved
        assert [r.name for r in rs.dag_rules()] == ["a", "b"]

    def test_after_reorders_and_stability(self):
        """Unconstrained rules keep their relative registry positions."""
        rs = RuleSet()
        for n in ("r1", "r2", "r3"):
            rs.register(self._noop(n))
        rs.register(self._noop("early", after=()))
        rs.register(self._noop("r1follower", after=("r1",)))
        assert [r.name for r in rs.resolve()] == \
            ["r1", "r2", "r3", "early", "r1follower"]
        rs2 = RuleSet()
        rs2.register(self._noop("late", after=("z",)))
        rs2.register(self._noop("z"))
        assert [r.name for r in rs2.resolve()] == ["z", "late"]

    def test_cycle_raises_clear_error(self):
        rs = RuleSet()
        rs.register(self._noop("x", before=("y",)))
        rs.register(self._noop("y", before=("x",)))
        with pytest.raises(ValueError, match="cycle"):
            rs.resolve()

    def test_constraints_on_absent_rules_ignored(self):
        """A rule may order itself against an optional/excluded peer."""
        rs = RuleSet()
        rs.register(self._noop("solo", before=("not-registered",),
                               after=("also-missing",)))
        assert [r.name for r in rs.resolve()] == ["solo"]
        # selection restricted to a subset ignores cross-subset edges too
        rs.register(self._noop("other", after=("solo",)))
        assert [r.name for r in rs.resolve(["other"])] == ["other"]

    def test_config_resolution_honors_constraints(self):
        """OptimizerConfig.resolve_rules goes through the topological sort:
        a user rule declaring after="T5" fires after T5 even though
        with_rule appends it... and one declaring before="toFIR" jumps the
        whole built-in pipeline (it must sit in the `normalize` phase to do
        so — ordering never crosses phase boundaries)."""
        first = self._noop("user-first", before=("toFIR",), phase="normalize")
        rules = RuleSet.default().with_rule(first)
        cfg = OptimizerConfig(rule_set=rules)
        names = [r.name for r in cfg.resolve_rules()]
        assert names.index("user-first") < names.index("toFIR")
        # the constrained set still compiles programs end to end
        session = CobraSession(make_wilos_db(100, ratio=10),
                               CostCatalog(SLOW_REMOTE), config=cfg)
        assert session.compile(make_wilos_e()).run(worklist=[1]).outputs

    def test_duplicate_selection_dedups_not_false_cycle(self):
        """A repeated name in the selection must resolve cleanly (first
        position wins), not misreport an empty 'cycle'."""
        rs = RuleSet()
        rs.register(self._noop("a", after=("b",)))
        rs.register(self._noop("b"))
        assert [r.name for r in rs.resolve(["a", "a", "b"])] == ["b", "a"]
        assert [r.name for r in rs.resolve(["b", "a", "b"])] == ["b", "a"]

    def test_cycle_surfaces_through_config(self):
        rs = RuleSet()
        rs.register(self._noop("p", after=("q",)))
        rs.register(self._noop("q", after=("p",)))
        with pytest.raises(ValueError, match="cycle"):
            OptimizerConfig(rule_set=rs).resolve_rules()

    def test_describe_shows_constraints(self):
        r = self._noop("shown", before=("T5",), after=("toFIR",))
        assert "before=['T5']" in r.describe()
        assert "after=['toFIR']" in r.describe()


# --------------------------------------------------------------------------
# Pluggable cost model
# --------------------------------------------------------------------------

class TestPluggableCostModel:
    def test_custom_cost_model_changes_winner(self):
        """A cost model that makes prefetching free forces the prefetch
        alternative to win where the built-in model keeps the aggregate
        query — the protocol is genuinely in control of plan choice."""
        class PrefetchLover(CostModel):
            revision = "test-1"

            def prefetch_cost(self, q):
                return 0.0

        session = wilos_session()
        builtin = session.compile(make_scan())
        custom = session.compile(
            make_scan(),
            config=dataclasses.replace(session.config,
                                       cost_model=PrefetchLover))
        assert plan_kind(builtin) == "query"
        assert plan_kind(custom) == "prefetch"

    def test_cost_model_identity_in_cache_key(self):
        class M(CostModel):
            pass

        base = OptimizerConfig()
        assert base.cache_key() != dataclasses.replace(
            base, cost_model=M).cache_key()

    def test_cost_model_receives_context(self):
        seen = {}

        class Spy(CostModel):
            def __init__(self, db, catalog, context=None):
                super().__init__(db, catalog, context)
                seen["context"] = self.context

        session = wilos_session()
        ctx = ExecutionContext(batch_size=7)
        session.compile(make_scan(), context=ctx,
                        config=dataclasses.replace(session.config,
                                                   cost_model=Spy))
        assert seen["context"] is ctx

    def test_non_class_cost_model_rejected(self):
        with pytest.raises(TypeError):
            OptimizerConfig(cost_model=42)

    def test_cost_model_gets_source_hash_revision(self):
        """Editing a custom model's body must change its cache identity
        (same safeguard user rules get); an explicit `revision` pins it."""
        class M(CostModel):
            pass

        key = OptimizerConfig(cost_model=M)._cost_model_key()
        assert key[-1] not in ("", None)

        class Pinned(CostModel):
            revision = "v7"

        assert OptimizerConfig(
            cost_model=Pinned)._cost_model_key()[-1] == "v7"

    def test_rules_override_path_keys_on_cost_model(self):
        """The back-compat `rules=` compile path must not collide across
        cost models."""
        class M(CostModel):
            pass

        session = wilos_session()
        rules = session.config.resolve_rules()
        a = session._cache_key(make_scan(), session.catalog, session.config,
                               rules)
        b = session._cache_key(make_scan(), session.catalog,
                               dataclasses.replace(session.config,
                                                   cost_model=M), rules)
        assert a != b

    def test_query_has_params_helper(self):
        from repro.relational.algebra import Not, Project
        assert not query_has_params(Scan("tasks"))
        assert query_has_params(
            Select(Cmp("==", Col("t_state"), Param("k")), Scan("tasks")))
        # params hiding under unary/odd scalar shapes must still be found
        # (misclassifying one as binding-free would wrongly amortize it)
        assert query_has_params(
            Select(Not(Cmp("==", Col("t_state"), Param("k"))), Scan("tasks")))
        assert query_has_params(
            Project((), Scan("tasks"), computed=(("v", Param("p")),)))


# --------------------------------------------------------------------------
# End-to-end: serving compiles a different plan than one-shot
# --------------------------------------------------------------------------

class TestServingContext:
    def test_serving_runtime_compiles_batch_aware_plan(self):
        """The same program, the same session: the serving runtime's
        registration compiles the batch-amortized winner while a plain
        one-shot compile keeps the per-iteration query."""
        session = wilos_session()
        one_shot = session.compile(make_scan())
        rt = ServingRuntime(session, batch_size=32)
        served = rt.register(make_scan())
        assert plan_kind(one_shot) == "query"
        assert plan_kind(served) == "prefetch"
        assert served.context.batch_size == 32

    def test_feedback_publishes_iterations_and_recompiles(self):
        """Observed while-iteration counts flow: interpreter -> batch
        observation log -> FeedbackController -> StatsProfile -> a
        context-driven recompile whose cost model uses the OBSERVED count."""
        session = wilos_session()
        rt = ServingRuntime(session, batch_size=2, feedback=True)
        rt.register(make_scan())
        # threshold never crossed -> the while runs all 5 states
        rt.serve([("SCAN", {"threshold": 1e9})] * 4)
        site = scan_while_site()
        profile = rt.feedback.stats_profile()
        assert profile.iters_for(site) == pytest.approx(5.0)
        assert rt.feedback.telemetry()["iters_publishes"] >= 1
        # the registered executable was recompiled under the observed stats
        exe = rt.executable("SCAN")
        assert exe.context.stats.iters_for(site) == pytest.approx(5.0)
        assert rt.context_recompiles >= 1

    def test_one_shot_session_unaffected_by_serving_plans(self):
        session = wilos_session()
        rt = ServingRuntime(session, batch_size=32)
        rt.register(make_scan())
        assert plan_kind(session.compile(make_scan())) == "query"


# --------------------------------------------------------------------------
# Context-pinned HW profile through the planner facade
# --------------------------------------------------------------------------

class TestContextHWProfile:
    def test_pinned_hw_changes_step_plan_cost_and_restores_global(self):
        from repro.analysis.roofline import HW
        base = CobraSession(make_wilos_db(50))
        ref = base.plan_step("rwkv6-3b", 2048, 16, "train")

        slow = CobraSession(make_wilos_db(50), context=ExecutionContext(
            hw={"peak_flops": HW["peak_flops"] / 10}))
        before = dict(HW)
        out = slow.plan_step("rwkv6-3b", 2048, 16, "train")
        assert HW == before                      # overlay fully restored
        assert out.est_cost_s > ref.est_cost_s   # the pin really costed it
        # distinct HW profiles occupy distinct step-cache entries
        assert slow.plan_step("rwkv6-3b", 2048, 16, "train") is out

    def test_one_shot_fingerprint_default_single_source(self):
        from repro.api import PlanCacheKey, PlanReport
        from repro.core import ONE_SHOT
        assert PlanCacheKey("fp", (), (), 1).context_key == \
            ONE_SHOT.fingerprint()
        assert PlanReport("program", "p", None, 0.0, 0, {}, 0.0,
                          None).context_fp == ONE_SHOT.fingerprint()
