"""Lifter inlining of small pure helper functions.

A helper whose body is simple ``name = expr`` assignments plus a single
trailing ``return expr`` — no loops, no branches, no queries — is inlined
by expression substitution at the call site, producing IR **byte-identical**
to the user substituting the expression by hand. Helpers outside that
subset raise a located :class:`~repro.api.lift.LiftError` naming the
constraint (and the generic not-liftable error still fires for
non-function callables).
"""

import pytest

from repro.api.lift import LiftError, lift_program, load_all

TAX = 0.2


def net_hours(h, factor=2.0):
    """Pure scalar helper: inlined at every call site."""
    scaled = h * factor
    return scaled - scaled * TAX


def double_net(h):
    # nested helper call: inlines recursively
    return net_hours(h) + net_hours(h, 3.0)


def has_loop(h):
    t = 0.0
    for _ in (1, 2):
        t = t + h
    return t


def has_comprehension(h):
    return sum(x for x in (h, h))


def no_return(h):
    h = h + 1


def multi_statement(h):
    if h > 0:
        return h
    return -h


def uses_query(h):
    from repro.api.builder import q
    rows = q("tasks")
    return h


def test_helper_inlines_byte_identical():
    def with_helper():
        acc = 0.0
        for t in load_all("tasks"):
            acc = acc + net_hours(t.t_hours)
        return acc

    def manual():
        acc = 0.0
        for t in load_all("tasks"):
            acc = acc + ((t.t_hours * 2.0) - (t.t_hours * 2.0) * TAX)
        return acc

    lifted = lift_program(with_helper, name="P")
    hand = lift_program(manual, name="P")
    assert lifted.body.key() == hand.body.key()
    assert repr(lifted.body) == repr(hand.body)


def test_helper_inlines_with_kwargs_and_defaults():
    def with_kw():
        acc = 0.0
        for t in load_all("tasks"):
            acc = acc + net_hours(t.t_hours, factor=4.0)
        return acc

    def manual():
        acc = 0.0
        for t in load_all("tasks"):
            acc = acc + ((t.t_hours * 4.0) - (t.t_hours * 4.0) * TAX)
        return acc

    assert (lift_program(with_kw, name="P").body.key()
            == lift_program(manual, name="P").body.key())


def test_nested_helper_inlines():
    def nested():
        acc = 0.0
        for t in load_all("tasks"):
            acc = acc + double_net(t.t_hours)
        return acc

    def manual():
        acc = 0.0
        for t in load_all("tasks"):
            acc = acc + (((t.t_hours * 2.0) - (t.t_hours * 2.0) * TAX)
                         + ((t.t_hours * 3.0) - (t.t_hours * 3.0) * TAX))
        return acc

    assert (lift_program(nested, name="P").body.key()
            == lift_program(manual, name="P").body.key())


@pytest.mark.parametrize("helper,needle", [
    (has_loop, "return"),             # loop body -> not a single return
    (has_comprehension, "GeneratorExp"),
    (no_return, "return"),
    (multi_statement, "If"),
    (uses_query, "ImportFrom"),
])
def test_unliftable_helper_raises_located_error(helper, needle):
    def prog():
        acc = 0.0
        for t in load_all("tasks"):
            acc = acc + helper(t.t_hours)
        return acc

    with pytest.raises(LiftError) as ei:
        lift_program(prog, name="P")
    msg = str(ei.value)
    assert f"cannot inline helper {helper.__name__}()" in msg
    assert needle in msg
    # located: the error points at the CALL site in this file
    assert "test_inline.py" in msg


def test_argument_mismatch_is_located():
    def prog():
        acc = 0.0
        for t in load_all("tasks"):
            acc = acc + net_hours(t.t_hours, 2.0, 3.0)
        return acc

    with pytest.raises(LiftError) as ei:
        lift_program(prog, name="P")
    assert "argument mismatch" in str(ei.value)
    assert "test_inline.py" in str(ei.value)


def test_query_marker_in_helper_rejected():
    from repro.api.builder import q

    def q_helper(h):
        rows = q("tasks")
        return h

    def prog():
        acc = 0.0
        for t in load_all("tasks"):
            acc = acc + q_helper(t.t_hours)
        return acc

    # the q() call is reachable whether rejected as a statement shape or
    # as a query-marker call — either way it must be a located LiftError
    with pytest.raises(LiftError):
        lift_program(prog, name="P")


def test_non_function_callable_still_generic_error():
    class NotAFunction:
        def __call__(self, x):
            return x

    inst = NotAFunction()

    def prog():
        acc = 0.0
        for t in load_all("tasks"):
            acc = acc + inst(t.t_hours)
        return acc

    with pytest.raises(LiftError) as ei:
        lift_program(prog, name="P")
    assert "cannot inline helper" not in str(ei.value)


def test_inlined_program_compiles_and_runs():
    from repro.api import CobraSession
    from repro.core import CostCatalog
    from repro.programs import make_wilos_db
    from repro.relational.database import SLOW_REMOTE

    def prog():
        acc = 0.0
        for t in load_all("tasks"):
            acc = acc + net_hours(t.t_hours)
        return acc

    sess = CobraSession(make_wilos_db(200, ratio=10),
                        CostCatalog(SLOW_REMOTE))
    exe = sess.compile(lift_program(prog, name="P"))
    out = exe.run().outputs
    assert out["acc"] == pytest.approx(
        sum((h * 2.0) - (h * 2.0) * TAX
            for h in sess.db.table("tasks").column("t_hours")))
