"""Compile-once/execute-many: the stats-versioned plan cache.

Issue acceptance: a repeated ``compile()`` of the same program is served
from the plan cache without re-running memo expansion, and ``db.analyze()``
after a stats change invalidates it — with a possibly different winning
plan under the new statistics.
"""

import numpy as np

from repro.api import (CobraSession, OptimizerConfig, PlanCache, PlanCacheKey,
                       program_fingerprint)
from repro.core import CostCatalog
from repro.programs import make_orders_customer_db, make_p0, make_sales_db
from repro.relational.database import SLOW_REMOTE


def fresh_session(n_orders=100, n_cust=5000, **cfg):
    db = make_orders_customer_db(n_orders, n_cust)
    config = OptimizerConfig.preset("paper-exp1-3", **cfg) if cfg else \
        OptimizerConfig.preset("paper-exp1-3")
    return CobraSession(db, CostCatalog(SLOW_REMOTE), config=config)


class TestCacheHits:
    def test_second_compile_skips_memo_search(self):
        session = fresh_session()
        exe1 = session.compile(make_p0())
        exe2 = session.compile(make_p0())
        assert not exe1.from_cache and exe2.from_cache
        # the memo search ran exactly once for two compiles
        assert session.memo_runs == 1 and session.compile_calls == 2
        assert session.plan_cache.hits == 1
        # the cached executable carries the identical plan/program
        assert exe2.result is exe1.result
        assert exe2.program.body.key() == exe1.program.body.key()

    def test_fingerprint_distinguishes_input_defaults(self):
        """Same body, different declared input defaults -> different run()
        semantics, so they must not share a cache entry."""
        from repro.api import ProgramBuilder

        def build(default):
            b = ProgramBuilder("t")
            w = b.input("w", default)
            r = b.let("r", b.empty_list())
            with b.loop(w, var="x") as x:
                b.add(r, x)
            return b.build(outputs=(r,))

        assert program_fingerprint(build((1, 2))) != \
            program_fingerprint(build((9, 9)))
        assert program_fingerprint(build((1, 2))) == \
            program_fingerprint(build((1, 2)))

    def test_fingerprint_ignores_program_name(self):
        """Two structurally identical programs share one cache entry."""
        session = fresh_session()
        session.compile(make_p0())
        renamed = make_p0()
        renamed = type(renamed)("P0_other_name", renamed.body,
                                renamed.outputs, renamed.inputs)
        assert program_fingerprint(renamed) == program_fingerprint(make_p0())
        assert session.compile(renamed).from_cache

    def test_cached_plan_still_runs(self):
        session = fresh_session(500, 100)
        out1 = session.compile(make_p0()).run()
        out2 = session.compile(make_p0()).run()
        a = np.sort(np.asarray(out1["result"], dtype=np.float64))
        b = np.sort(np.asarray(out2["result"], dtype=np.float64))
        assert np.allclose(a, b)

    def test_distinct_configs_do_not_collide(self):
        session = fresh_session()
        exe_paper = session.compile(make_p0())
        exe_full = session.compile(make_p0(),
                                   config=OptimizerConfig.preset("full"))
        assert not exe_full.from_cache          # different rule set: fresh run
        assert session.memo_runs == 2
        exe_full2 = session.compile(make_p0(),
                                    config=OptimizerConfig.preset("full"))
        assert exe_full2.from_cache
        assert exe_paper.result is not exe_full.result

    def test_distinct_catalogs_do_not_collide(self):
        session = fresh_session()
        session.compile(make_p0())
        exe_af = session.compile(make_p0(),
                                 catalog=CostCatalog(SLOW_REMOTE, af=50.0))
        assert not exe_af.from_cache

    def test_cache_opt_out(self):
        session = fresh_session(use_plan_cache=False)
        session.compile(make_p0())
        session.compile(make_p0())
        assert session.memo_runs == 2 and len(session.plan_cache) == 0


class TestStatsVersionInvalidation:
    def test_analyze_bumps_version_monotonically(self):
        db = make_sales_db(100)
        v0 = db.stats_version
        v1 = db.analyze()
        v2 = db.analyze()
        assert v0 < v1 < v2

    def test_analyze_invalidates_cached_plan(self):
        session = fresh_session()
        exe1 = session.compile(make_p0())
        session.analyze()                       # stats refresh -> version bump
        exe2 = session.compile(make_p0())
        assert not exe2.from_cache and session.memo_runs == 2
        assert session.plan_cache.invalidations >= 1

    def test_data_change_flips_winning_plan(self):
        """Issue acceptance: after the data (and thus statistics) change,
        recompilation may pick a different winner — here P1 (join) at few
        orders/many customers flips to P2 (prefetch) once the join result
        dominates transfer."""
        session = fresh_session(100, 5000)
        exe1 = session.compile(make_p0())
        assert "JOIN" in repr(exe1.program.body)

        # replace the tables with a cardinality profile where the join
        # output dominates, then refresh statistics
        grown = make_orders_customer_db(4000, 500)
        session.db.add_table(grown.table("orders"))
        session.db.add_table(grown.table("customer"))
        session.db.analyze()

        exe2 = session.compile(make_p0())
        assert not exe2.from_cache
        assert "prefetch" in repr(exe2.program.body)
        # and the new plan still computes the same answer as the original
        base = session.execute(make_p0())
        opt = exe2.run()
        a = np.sort(np.asarray(base["result"], dtype=np.float64))
        b = np.sort(np.asarray(opt["result"], dtype=np.float64))
        assert np.allclose(a, b, rtol=1e-4)

    def test_update_through_interpreter_bumps_version(self):
        """Programs that UPDATE rows change table statistics; the version
        must move so stale plans cannot be served afterwards."""
        from repro.programs import make_wilos_a, make_wilos_db
        from repro.relational.database import FAST_LOCAL
        session = CobraSession(make_wilos_db(200), CostCatalog(FAST_LOCAL))
        v0 = session.db.stats_version
        session.compile(make_wilos_a()).run()
        assert session.db.stats_version > v0


class TestPlanCacheUnit:
    def _key(self, fp, v):
        return PlanCacheKey(fp, ("cat",), ("cfg",), v)

    def test_lru_eviction(self):
        c = PlanCache(max_entries=2)
        c.put(self._key("a", 1), "A")
        c.put(self._key("b", 1), "B")
        assert c.get(self._key("a", 1)) == "A"   # refresh 'a'
        c.put(self._key("c", 1), "C")            # evicts 'b' (LRU)
        assert c.get(self._key("b", 1)) is None
        assert c.get(self._key("a", 1)) == "A"
        assert c.evictions == 1

    def test_invalidation_counter_vs_cold_miss(self):
        c = PlanCache()
        assert c.get(self._key("a", 1)) is None
        assert c.invalidations == 0              # cold miss, nothing stale
        c.put(self._key("a", 1), "A")
        assert c.get(self._key("a", 2)) is None  # stale sibling exists
        assert c.invalidations == 1

    def test_drop_stale(self):
        c = PlanCache()
        c.put(self._key("a", 1), "A")
        c.put(self._key("b", 2), "B")
        assert c.drop_stale(current_stats_version=2) == 1
        assert len(c) == 1 and c.get(self._key("b", 2)) == "B"

    def test_stats_shape(self):
        c = PlanCache()
        s = c.stats()
        assert set(s) == {"entries", "hits", "misses", "evictions",
                          "invalidations"}
