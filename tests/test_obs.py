"""Observability layer: tracer spans, unified metrics, signals, EXPLAIN.

Issue acceptance:
  * ``scan_plan`` detects distinct bad-plan patterns on the naive example
    programs, and each signal DISAPPEARS after the optimizer's rewrite;
  * registry-backed counters reconcile bit-for-bit with the legacy
    telemetry dict views;
  * span trees stay well-nested through mid-stream ``analyze()`` /
    ``replace_table`` / drift-driven plan swaps;
  * tracing on vs off never changes outputs or the simulated clock;
  * ``explain()`` shows the rules that fired and per-site estimated-vs-
    observed counts; ``PlanReport`` carries tier + swap-guard outcome.
"""

import json

import pytest

from repro.api import CobraSession, ExecutionContext, OptimizerConfig
from repro.core import CostCatalog
from repro.core.context import StatsProfile
from repro.api.cache import program_param_sites
from repro.obs import (MetricsRegistry, NoopTracer, Tracer, fmt_seconds,
                       merge_snapshots, render_triage, scan_plan)
from repro.obs.explain import q_error
from repro.programs import (make_m0, make_orders_customer_db, make_p0,
                            make_sales_db, make_scan, make_wilos_a,
                            make_wilos_db, make_wilos_e)
from repro.relational.database import FAST_LOCAL, SLOW_REMOTE
from repro.runtime import ServingRuntime


def paper_session(db, network=SLOW_REMOTE, **kw):
    return CobraSession(db, CostCatalog(network),
                        config=OptimizerConfig.preset("paper-exp1-3"), **kw)


def drifted_session(**kw):
    """Compile against 100 orders / 5000 customers; the caller bulk-loads
    the 4000/500 profile without ANALYZE to go stale (test_runtime idiom)."""
    session = paper_session(make_orders_customer_db(100, 5000), **kw)
    grown = make_orders_customer_db(4000, 500)
    return session, grown


# --------------------------------------------------------------------------
# MetricsRegistry
# --------------------------------------------------------------------------

class TestMetricsRegistry:
    def test_counters_and_labels(self):
        m = MetricsRegistry()
        m.inc("requests")
        m.inc("requests", 2)
        m.inc("requests", program="P0")
        assert m.value("requests") == 3
        assert m.value("requests", program="P0") == 1
        assert m.value("never_written") == 0

    def test_gauge_and_histogram(self):
        m = MetricsRegistry()
        m.gauge("stats_version", 7)
        m.gauge("stats_version", 9)
        assert m.gauge_value("stats_version") == 9
        for v in (1.0, 3.0, 2.0):
            m.observe("opt_time_s", v)
        h = m.histogram("opt_time_s")
        assert h["count"] == 3 and h["sum"] == 6.0
        assert h["min"] == 1.0 and h["max"] == 3.0

    def test_snapshot_and_diff(self):
        m = MetricsRegistry()
        m.inc("a")
        m.gauge("g", 1)
        older = m.snapshot()
        m.inc("a", 4)
        m.inc("b", program="P0")
        d = m.diff(older)
        assert d["a"] == 4
        assert d["b{program=P0}"] == 1
        assert "g" not in d                      # unchanged values drop out

    def test_ingest_and_merge(self):
        m = MetricsRegistry()
        m.ingest({"hits": 3, "misses": 1, "describe": "text"}, prefix="cache_")
        assert m.snapshot() == {"cache_hits": 3, "cache_misses": 1}
        snap = merge_snapshots(serving=m.snapshot())
        assert snap["serving_cache_hits"] == 3

    def test_fmt_seconds(self):
        assert fmt_seconds(None) == "—"
        assert fmt_seconds(2.5) == "2.50s"
        assert fmt_seconds(0.012) == "12.0ms"
        assert fmt_seconds(3e-5) == "30µs"

    def test_q_error_symmetric(self):
        assert q_error(100, 100) == 1.0
        assert q_error(100, 4000) == q_error(4000, 100) > 39


# --------------------------------------------------------------------------
# Registry-backed counters reconcile with legacy telemetry views
# --------------------------------------------------------------------------

class TestCounterReconciliation:
    def test_session_counters_are_registry_views(self):
        session = paper_session(make_orders_customer_db(200, 100))
        exe = session.compile(make_p0())
        exe.run()
        exe.run_batch([{}] * 3)
        t = session.telemetry
        for key in ("compile_calls", "memo_runs", "executions"):
            assert t[key] == getattr(session, key) \
                == session.metrics.value(key)
        assert session.executions == 4           # 1 run + batch of 3

    def test_serving_counters_reconcile_bit_for_bit(self):
        session = paper_session(make_orders_customer_db(200, 100))
        rt = ServingRuntime(session, batch_size=4)
        rt.register(make_p0())
        rt.serve([("P0", {})] * 8)
        t = rt.telemetry()
        for tkey, attr in (("requests_served", "requests_served"),
                           ("batches_run", "batches_run"),
                           ("recompiles", "recompiles"),
                           ("round_trips", "n_round_trips"),
                           ("simulated_s", "simulated_s")):
            assert t[tkey] == getattr(rt, attr) == rt.metrics.value(attr)
        ft = rt.feedback.telemetry()
        assert ft["stats_refreshes"] == rt.feedback.refreshes \
            == rt.feedback.metrics.value("refreshes")
        assert ft["observed_queries"] \
            == rt.feedback.metrics.value("observed_queries")

    def test_compiler_counters_reconcile(self):
        session = paper_session(make_orders_customer_db(300, 30), FAST_LOCAL)
        rt = ServingRuntime(session, batch_size=8, compile_hot_plans=2)
        rt.register(make_p0())
        rt.serve([("P0", {})] * 24)
        ct = rt.compiler.telemetry()
        for key in ("compiles", "compiled_batches", "interpreted_batches"):
            assert ct[key] == getattr(rt.compiler, key) \
                == rt.compiler.metrics.value(key)
        snap = rt.metrics_snapshot()
        assert snap["serving_compiled_compiles"] == ct["compiles"]
        assert snap["serving_requests_served"] == rt.requests_served
        assert snap["session_executions"] == session.executions
        assert snap["feedback_refreshes"] == rt.feedback.refreshes

    def test_external_increments_route_through_registry(self):
        session = paper_session(make_orders_customer_db(100, 50))
        session.plan_swaps_accepted = session.plan_swaps_accepted + 5
        assert session.metrics.value("plan_swaps_accepted") == 5


# --------------------------------------------------------------------------
# Tracer
# --------------------------------------------------------------------------

class TestTracer:
    def test_manual_spans_well_nested(self):
        tr = Tracer()
        with tr.span("outer", workload="x"):
            with tr.span("inner"):
                pass
            tr.event("tick", n=1)
        assert tr.well_nested()
        (outer,) = tr.spans("outer")
        assert [c.name for c in outer.children] == ["inner", "tick"]
        assert outer.wall_s >= outer.children[0].wall_s
        assert "outer" in tr.render() and "inner" in tr.render()

    def test_export_jsonl(self, tmp_path):
        tr = Tracer()
        with tr.span("a"):
            tr.event("b")
        path = tmp_path / "trace.jsonl"
        assert tr.export_jsonl(str(path)) == 2
        recs = [json.loads(l) for l in path.read_text().splitlines()]
        assert recs[0]["name"] == "a" and recs[0]["parent"] is None
        assert recs[1]["parent"] == recs[0]["id"]

    def test_compile_emits_phase_span_tree(self):
        tracer = Tracer()
        session = paper_session(make_orders_customer_db(100, 50),
                                tracer=tracer)
        session.compile(make_p0())
        assert tracer.well_nested()
        (comp,) = tracer.spans("compile")
        names = [c.name for c in comp.children]
        assert names[0] == "build-memo" and names[-1] == "codegen"
        assert "saturate" in names and "search" in names
        (sat,) = tracer.spans("saturate")
        assert sat.children and all(c.name == "saturate-round"
                                    for c in sat.children)

    def test_spans_stay_nested_through_drift_swap(self):
        """Mid-stream analyze()/replace_table/plan swap must not corrupt
        the span stack."""
        tracer = Tracer()
        session, grown = drifted_session(tracer=tracer)
        rt = ServingRuntime(session, batch_size=4, drift_threshold=3.0)
        rt.register(make_p0())
        rt.serve([("P0", {})] * 4)
        session.db.replace_table(grown.table("orders"))
        session.db.replace_table(grown.table("customer"))
        rt.serve([("P0", {})] * 8)
        assert rt.recompiles >= 1
        assert tracer.well_nested()
        assert tracer.spans("serve") and tracer.spans("batch")
        verdicts = tracer.spans("swap-verdict")
        assert verdicts and verdicts[0].attrs["accepted"] is True
        # batch spans carry the simulated clock alongside the wall clock
        batches = tracer.spans("batch")
        assert any(b.sim_s and b.sim_s > 0 for b in batches)

    def test_tracing_never_changes_outputs_or_clock(self):
        """Bit-identity: the same stream served traced and untraced, through
        a drift-driven swap, yields equal outputs and simulated clocks."""
        def run(tracer):
            session, grown = drifted_session(tracer=tracer)
            rt = ServingRuntime(session, batch_size=4, drift_threshold=3.0)
            rt.register(make_p0())
            out = list(rt.serve([("P0", {})] * 4))
            session.db.replace_table(grown.table("orders"))
            session.db.replace_table(grown.table("customer"))
            out += list(rt.serve([("P0", {})] * 8))
            return out, rt.simulated_s

        traced_out, traced_sim = run(Tracer())
        plain_out, plain_sim = run(None)
        assert traced_sim == plain_sim               # exact, not approx
        assert [r.outputs for r in traced_out] == \
            [r.outputs for r in plain_out]
        assert [r.simulated_s for r in traced_out] == \
            [r.simulated_s for r in plain_out]

    def test_noop_tracer_records_nothing(self):
        session = paper_session(make_orders_customer_db(100, 50))
        assert isinstance(session.tracer, NoopTracer)
        session.compile(make_p0()).run()
        assert session.tracer.spans() == []


# --------------------------------------------------------------------------
# Bad-plan signals: detected naive, gone after the rewrite
# --------------------------------------------------------------------------

class TestScanPlan:
    def test_p0_n_plus_one_detected_then_rewritten_away(self):
        found = scan_plan(make_p0())
        assert [s.kind for s in found] == ["n_plus_one"]
        assert found[0].severity == pytest.approx(0.8)
        session = paper_session(make_orders_customer_db(300, 600))
        assert session.compile(make_p0()).scan() == []

    def test_scan_query_in_while_detected_then_rewritten_away(self):
        found = scan_plan(make_scan())
        assert {s.kind for s in found} == {"query_in_while"}
        session = paper_session(make_wilos_db(300, ratio=10))
        exe = session.compile(make_scan(),
                              context=ExecutionContext(batch_size=16))
        assert "prefetch" in repr(exe.program.body)
        assert exe.scan() == []

    def test_wilos_a_unbatched_writes_detected(self):
        found = scan_plan(make_wilos_a())
        assert "unbatched_writes" in {s.kind for s in found}

    def test_wilos_e_n_plus_one_then_prefetch_rewrite(self):
        assert "n_plus_one" in {s.kind for s in scan_plan(make_wilos_e())}
        session = paper_session(make_wilos_db(300, ratio=10), FAST_LOCAL)
        exe = session.compile(make_wilos_e(),
                              context=ExecutionContext(batch_size=64))
        assert "prefetch" in repr(exe.program.body)
        assert exe.scan() == []

    def test_diverse_bindings_from_observed_stats(self):
        we = make_wilos_e()
        groups = program_param_sites(we)
        assert groups
        hostile = StatsProfile.of(bindings={g: 1.0 for g in groups})
        found = scan_plan(we, stats=hostile)
        assert "diverse_bindings" in {s.kind for s in found}
        friendly = StatsProfile.of(bindings={g: 0.1 for g in groups})
        assert "diverse_bindings" not in {
            s.kind for s in scan_plan(we, stats=friendly)}

    def test_interpreter_hot_loop_needs_heat(self):
        session = paper_session(make_wilos_db(200, ratio=10))
        exe = session.compile(make_wilos_a())
        cold = {s.kind for s in exe.scan()}
        assert "interpreter_hot_loop" not in cold
        for _ in range(3):
            exe.run()
        hot = {s.kind for s in exe.scan()}
        assert "interpreter_hot_loop" in hot

    def test_clean_program_yields_no_signals(self):
        assert scan_plan(make_m0()) == []

    def test_signals_rank_most_severe_first(self):
        sigs = scan_plan(make_wilos_a())
        assert [s.severity for s in sigs] == \
            sorted((s.severity for s in sigs), reverse=True)


# --------------------------------------------------------------------------
# EXPLAIN + PlanReport tier/swap fields + triage
# --------------------------------------------------------------------------

class TestExplainAndTriage:
    def test_explain_we_shows_rules_and_est_vs_observed(self):
        """Acceptance: explain() for W_E shows the rules that fired and
        per-site estimated-vs-observed counts."""
        session = paper_session(make_wilos_db(300, ratio=10), FAST_LOCAL)
        rt = ServingRuntime(session, batch_size=8, drift_threshold=1e9)
        rt.register(make_wilos_e())
        rt.serve([("W_E", {"worklist": [i % 4]}) for i in range(16)])
        text = rt.explain("W_E")
        assert "EXPLAIN W_E" in text
        assert "rules fired (winning plan):" in text
        assert "est " in text and "observed " in text
        assert "q-error" in text
        assert "tier: interpreter" in text

    def test_report_tier_after_hot_promotion(self):
        session = paper_session(make_orders_customer_db(300, 30), FAST_LOCAL)
        rt = ServingRuntime(session, batch_size=8, compile_hot_plans=2)
        rt.register(make_p0())
        exe = rt.executable("P0")
        assert exe.report.tier == "interpreter"
        rt.serve([("P0", {})] * 24)
        assert exe.report.tier == "compiled"
        assert "tier: compiled" in rt.explain("P0")

    def test_report_swap_fields_after_drift(self):
        session, grown = drifted_session()
        rt = ServingRuntime(session, batch_size=4, drift_threshold=3.0)
        rt.register(make_p0())
        session.db.replace_table(grown.table("orders"))
        session.db.replace_table(grown.table("customer"))
        rt.serve([("P0", {})] * 8)
        assert rt.recompiles >= 1
        r = rt.executable("P0").report
        assert r.swap_checked and r.swap_accepted is True
        assert r.swap_replayed > 0
        assert "swap-guard accepted" in rt.explain("P0")

    def test_triage_ranks_by_traffic_weighted_win(self):
        session, grown = drifted_session()
        session.db.add_table(make_sales_db(300).table("sales"))
        rt = ServingRuntime(session, batch_size=4, drift_threshold=3.0)
        rt.register(make_p0())
        rt.register(make_m0())
        session.db.replace_table(grown.table("orders"))
        session.db.replace_table(grown.table("customer"))
        rt.serve([("P0", {})] * 8 + [("M0", {})] * 4)
        rows = rt.triage()
        assert [r.name for r in rows][0] == "P0"     # drifted + most traffic
        p0, m0 = rows[0], next(r for r in rows if r.name == "M0")
        assert p0.drift > 3.0 and m0.drift == 1.0
        assert p0.score > m0.score
        assert abs(sum(r.share for r in rows) - 1.0) < 1e-9
        table = render_triage(rows)
        assert table.splitlines()[0].startswith("| program |")
        assert "P0" in table
        assert "drift" in p0.describe() and "score" in p0.describe()
