"""The serving-level shared SiteCache: cross-batch/cross-program MQO,
write-set-aware batching, invalidation races.

Issue acceptance:
  * a cross-batch SiteCache hit is served on the SECOND batch of an
    identical workload (one fetch per site per stats epoch, not per batch);
  * a mutating program shares at least one read-only site under write-set
    analysis (the all-or-nothing sequential fallback is gone);
  * every cached execution is bit-identical to uncached execution — in
    particular, a concurrent ``analyze()`` / table write landing between
    (or inside) batches must never let a stale site result be served
    (epoch keys: per-table stats + data versions);
  * TTL expiry, LRU bound, eager ``invalidate_tables``, and the per-site
    binding-diversity observation the feedback loop publishes.
"""

import numpy as np
import pytest

from repro.api import (CobraSession, OptimizerConfig, program_read_tables,
                       program_write_tables)
from repro.api.lift import lift_program, load_all, update_row
from repro.core import CostCatalog
from repro.programs import (make_orders_customer_db, make_p0, make_wilos_a,
                            make_wilos_b, make_wilos_db, make_wilos_e)
from repro.relational.algebra import Scan, scan_tables
from repro.relational.database import FAST_LOCAL, SLOW_REMOTE
from repro.runtime import BatchClientEnv, ServingRuntime, SiteCache
from repro.runtime.sitecache import param_key


def paper_session(db, network=SLOW_REMOTE):
    return CobraSession(db, CostCatalog(network),
                        config=OptimizerConfig.preset("paper-exp1-3"))


class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


# --------------------------------------------------------------------------
# SiteCache unit behavior: epoch keys, TTL, LRU, invalidation
# --------------------------------------------------------------------------

class TestSiteCacheUnit:
    def _db(self):
        return make_wilos_db(100, ratio=10)

    def test_epoch_key_misses_after_analyze(self):
        db = self._db()
        cache = SiteCache()
        q = Scan("tasks")
        key = cache.site_key(q, (), db.site_epoch(("tasks",)))
        cache.put(key, "result", ("tasks",))
        assert cache.get(key) == "result"
        db.analyze("tasks")
        fresh = cache.site_key(q, (), db.site_epoch(("tasks",)))
        assert fresh != key
        assert cache.get(fresh) is None        # stats epoch moved: miss

    def test_epoch_key_misses_after_data_write_without_analyze(self):
        """replace_table changes ROWS but not statistics — the data version
        alone must move the epoch (this is what keeps cached executions
        bit-identical: stale rows are unreachable, not just unlikely)."""
        db = self._db()
        cache = SiteCache()
        q = Scan("tasks")
        key = cache.site_key(q, (), db.site_epoch(("tasks",)))
        cache.put(key, "old rows", ("tasks",))
        v = db.table_version("tasks")
        db.replace_table(make_wilos_db(400, ratio=10).table("tasks"))
        assert db.table_version("tasks") == v          # stats untouched...
        assert db.site_epoch(("tasks",)) != key[2]     # ...epoch moved anyway
        assert cache.get(cache.site_key(q, (),
                                        db.site_epoch(("tasks",)))) is None

    def test_ttl_expires_entries(self):
        clock = FakeClock()
        cache = SiteCache(ttl_s=10.0, clock=clock)
        cache.put(("k",), "v", ("tasks",))
        clock.now = 9.0
        assert cache.get(("k",)) == "v"
        clock.now = 11.0
        assert cache.get(("k",)) is None
        assert cache.expirations == 1 and len(cache) == 0

    def test_lru_bound_evicts_oldest(self):
        cache = SiteCache(max_entries=2)
        cache.put(("a",), 1, ())
        cache.put(("b",), 2, ())
        assert cache.get(("a",)) == 1      # refresh a's recency
        cache.put(("c",), 3, ())
        assert cache.evictions == 1
        assert cache.get(("b",)) is None   # b was LRU
        assert cache.get(("a",)) == 1 and cache.get(("c",)) == 3

    def test_invalidate_tables_drops_matching_entries(self):
        cache = SiteCache()
        cache.put(("t",), 1, ("tasks",))
        cache.put(("r",), 2, ("roles",))
        cache.put(("tr",), 3, ("roles", "tasks"))
        assert cache.invalidate_tables(["tasks"]) == 2
        assert cache.invalidations == 2
        assert cache.get(("r",)) == 2
        assert cache.get(("t",)) is None and cache.get(("tr",)) is None

    def test_validation(self):
        with pytest.raises(ValueError, match="ttl_s"):
            SiteCache(ttl_s=0)
        with pytest.raises(ValueError, match="max_entries"):
            SiteCache(max_entries=0)

    def test_binding_diversity_observation(self):
        cache = SiteCache()
        from repro.relational.algebra import Cmp, Col, Param, Select
        q = Select(Cmp("==", Col("t_role_id"), Param("rid")), Scan("tasks"))
        for rid in (1, 1, 2, 1):
            cache.observe_binding(q, scan_tables(q),
                                  param_key({"rid": rid}))
        (stats,) = cache.site_binding_stats().values()
        assert stats["lookups"] == 4 and stats["distinct"] == 2
        assert stats["fraction"] == pytest.approx(0.5)
        # published at BOTH granularities: the coarse per-table group and
        # the provenance group (tables + param-compared columns)
        fracs = cache.binding_fractions()
        assert sorted(g.split(":")[0] for g in fracs) == ["qdiv", "qprov"]
        for frac in fracs.values():
            assert frac == pytest.approx(0.5)

    def test_stats_and_describe_shape(self):
        cache = SiteCache()
        assert set(cache.stats()) >= {"entries", "hits", "shared_hits",
                                      "misses", "hit_rate", "expirations",
                                      "evictions", "invalidations"}
        assert "SiteCache" in cache.describe()


# --------------------------------------------------------------------------
# Acceptance: cross-batch and cross-program sharing
# --------------------------------------------------------------------------

class TestCrossBatchSharing:
    def test_second_identical_batch_hits_shared_cache(self):
        """THE acceptance counter: the second batch of an identical
        workload is served from the first batch's fetches — zero new round
        trips, bit-identical outputs."""
        session = paper_session(make_orders_customer_db(300, 100))
        exe = session.compile(make_p0())
        cache = SiteCache()
        single = exe.run()
        b1 = exe.run_batch([{}] * 4, site_cache=cache)
        b2 = exe.run_batch([{}] * 4, site_cache=cache)
        assert b1.shared_site_hits == 0
        assert b2.shared_site_hits > 0
        assert b2.n_round_trips == 0          # every site already resident
        for r in b1.results + b2.results:
            assert r.outputs == single.outputs

    def test_serving_runtime_shares_across_batches(self):
        session = paper_session(make_wilos_db(300, ratio=10))
        rt = ServingRuntime(session, batch_size=4, feedback=False)
        rt.register(make_wilos_e())
        rt.serve([("W_E", {"worklist": [1]})] * 4)
        assert rt.site_cache.shared_hits == 0
        before = rt.n_round_trips
        rt.serve([("W_E", {"worklist": [1]})] * 4)
        assert rt.site_cache.shared_hits > 0
        assert rt.n_round_trips == before     # second batch: all local
        assert rt.telemetry()["site_cache_shared_hits"] > 0

    def test_cross_program_site_sharing(self):
        """MQO at the serving layer: two DIFFERENT programs whose plans
        fetch the same site (Scan(tasks)) share one server fetch."""
        session = paper_session(make_wilos_db(300, ratio=10), FAST_LOCAL)
        rt = ServingRuntime(session, batch_size=4, feedback=False)
        rt.register(make_wilos_e())           # prefetch plan: fetches tasks
        rt.register(make_wilos_b())           # loadAll(tasks) site
        rt.serve([("W_E", {"worklist": [1]})] * 2)
        shared_before = rt.site_cache.shared_hits
        rt.serve([("W_B", {})] * 2)
        assert rt.site_cache.shared_hits > shared_before
        # and W_B's outputs are exactly what an uncached run computes
        base = session.execute(make_wilos_b())
        final = rt.serve([("W_B", {})])[0]
        assert final.outputs == base.outputs

    def test_private_cache_preserves_per_batch_behavior(self):
        """Without a serving-scoped cache, run_batch keeps the classic
        one-fetch-per-site-per-batch behavior (a fresh private cache)."""
        session = paper_session(make_orders_customer_db(200, 100))
        exe = session.compile(make_p0())
        sites = exe.run().n_round_trips
        b1 = exe.run_batch([{}] * 3)
        b2 = exe.run_batch([{}] * 3)
        assert b1.n_round_trips == sites and b2.n_round_trips == sites
        assert b1.shared_site_hits == 0 and b2.shared_site_hits == 0


# --------------------------------------------------------------------------
# Acceptance: write-set-aware mutating programs
# --------------------------------------------------------------------------

class TestWriteSetSharing:
    def test_read_write_split(self):
        wa = make_wilos_a()
        assert program_write_tables(wa) == ("roles",)
        assert program_read_tables(wa) == ("tasks",)
        assert program_write_tables(make_p0()) == ()

    def test_mutating_program_shares_read_only_site(self):
        """Acceptance: W_A updates `roles` but only READS `tasks` — its
        tasks fetch is shared across the batch's isolated invocations,
        replacing the old all-or-nothing sequential fallback."""
        session = paper_session(make_wilos_db(200, ratio=10), FAST_LOCAL)
        exe = session.compile(make_wilos_a())
        batch = exe.run_batch([{}] * 3)
        assert not batch.batched              # still isolated invocations
        assert batch.site_hits >= 2           # tasks site shared twice

        # bit-identical to fully isolated sequential execution
        s2 = paper_session(make_wilos_db(200, ratio=10), FAST_LOCAL)
        e2 = s2.compile(make_wilos_a())
        for r in batch.results:
            assert r.outputs == e2.run().outputs
        assert np.array_equal(
            np.asarray(session.db.table("roles").column("r_rank")),
            np.asarray(s2.db.table("roles").column("r_rank")))

    def test_written_table_sites_never_cached(self):
        """A site over a table the program UPDATES is fetched fresh every
        time — each invocation must observe earlier invocations' writes."""
        def bump_then_read(worklist=()):
            out = []
            for wid in worklist:
                update_row("roles", "r_rank", 99, "r_id", wid)
            for r in load_all("roles"):
                out.append(r.r_rank)
            return out

        session = paper_session(make_wilos_db(100, ratio=10), FAST_LOCAL)
        exe = session.compile(lift_program(bump_then_read))
        cache = SiteCache()
        batch = exe.run_batch([{"worklist": [0]}, {"worklist": [1]}],
                              site_cache=cache)
        # the SECOND invocation sees BOTH writes (no stale roles snapshot)
        assert batch.results[1].outputs["out"][0] == 99
        assert batch.results[1].outputs["out"][1] == 99
        # and the first saw only its own
        assert batch.results[0].outputs["out"][0] == 99


# --------------------------------------------------------------------------
# Satellite: invalidation races — concurrent analyze()/write vs in-flight
# batches must never serve a stale site result
# --------------------------------------------------------------------------

class TestInvalidationRaces:
    def _grown(self, n=1200):
        return make_wilos_db(n, ratio=10)

    def test_analyze_between_batches_refetches(self):
        session = paper_session(make_wilos_db(200, ratio=10))
        exe = session.compile(make_wilos_b())
        cache = SiteCache()
        exe.run_batch([{}] * 2, site_cache=cache)
        session.db.analyze("tasks")
        b2 = exe.run_batch([{}] * 2, site_cache=cache)
        assert b2.shared_site_hits == 0       # epoch moved: nothing reused
        assert b2.n_round_trips >= 1

    def test_write_between_batches_never_serves_stale(self):
        """The bit-identity acceptance under mutation: data replaced (no
        ANALYZE — statistics still stale!) between two batches; the second
        batch must compute exactly what an uncached execution computes."""
        session = paper_session(self._grown(200), FAST_LOCAL)
        exe = session.compile(make_wilos_b())
        cache = SiteCache()
        b1 = exe.run_batch([{}] * 2, site_cache=cache)
        session.db.replace_table(self._grown().table("tasks"))
        b2 = exe.run_batch([{}] * 2, site_cache=cache)
        fresh = session.execute(make_wilos_b())
        assert b2.results[0].outputs == fresh.outputs
        assert b2.results[0].outputs != b1.results[0].outputs  # data moved
        assert b2.shared_site_hits == 0

    def test_write_mid_batch_never_serves_stale(self):
        """The PlanStore-race pattern at the SiteCache: a write lands while
        a batch env is in flight (between two lookups of the same site).
        The second lookup's epoch differs, so it refetches — the in-flight
        env observes the new rows exactly like an uncached client would."""
        db = self._grown(100)
        session = paper_session(db, FAST_LOCAL)
        cache = SiteCache()
        env = BatchClientEnv(db, FAST_LOCAL, site_cache=cache)
        q = Scan("tasks")
        t1 = env.execute_query(q)
        assert env.execute_query(q) is t1     # in-batch reuse while quiet
        db.replace_table(self._grown(300).table("tasks"))
        t2 = env.execute_query(q)             # write raced the batch
        assert t2.nrows == 300 and t1.nrows == 100
        assert cache.misses == 2              # the post-write lookup missed

    def test_analyze_mid_batch_refetches_same_rows(self):
        """A concurrent ANALYZE (stats only, same rows) mid-batch: the
        refetch is mandatory (epoch moved) but yields identical rows —
        correctness costs one round trip, never a wrong answer."""
        db = self._grown(100)
        session = paper_session(db, FAST_LOCAL)
        env = BatchClientEnv(db, FAST_LOCAL, site_cache=SiteCache())
        q = Scan("tasks")
        t1 = env.execute_query(q)
        db.analyze("tasks")
        t2 = env.execute_query(q)
        assert env.n_round_trips == 2         # the second lookup refetched
        assert env.site_hits == 0
        assert t2.to_rows() == t1.to_rows()

    def test_feedback_refresh_invalidates_site_cache(self):
        """The drift path: re-analyze drops the drifted tables' entries
        from the serving cache eagerly (epoch keys already orphaned them)."""
        db = make_orders_customer_db(100, 5000)
        session = paper_session(db)
        rt = ServingRuntime(session, batch_size=4, drift_threshold=3.0)
        rt.register(make_p0())
        grown = make_orders_customer_db(4000, 500)
        session.db.replace_table(grown.table("orders"))
        session.db.replace_table(grown.table("customer"))
        rt.serve([("P0", {})] * 8)
        assert rt.feedback.refreshes >= 1
        assert rt.site_cache.invalidations >= 0  # eager drop ran
        # post-drift responses still match uncached execution
        base = session.execute(make_p0())
        final = rt.serve([("P0", {})])[0]
        assert sorted(np.asarray(final["result"]).tolist()) == \
            pytest.approx(sorted(np.asarray(base["result"]).tolist()))


# --------------------------------------------------------------------------
# Review regressions: db identity, written-table amortization, saturation
# --------------------------------------------------------------------------

class TestReviewRegressions:
    def test_one_cache_two_databases_never_cross_serves(self):
        """Identically-named tables on two servers both start at epoch
        counters (1, 1) — the cache key's origin token (the server's
        instance_token) must keep them apart."""
        db_a = make_wilos_db(100, ratio=10, seed=2)
        db_b = make_wilos_db(100, ratio=10, seed=7)   # different rows!
        cache = SiteCache()
        env_a = BatchClientEnv(db_a, FAST_LOCAL, site_cache=cache)
        env_b = BatchClientEnv(db_b, FAST_LOCAL, site_cache=cache)
        q = Scan("tasks")
        t_a = env_a.execute_query(q)
        t_b = env_b.execute_query(q)
        assert cache.hits == 0 and cache.misses == 2  # no cross-db serving
        assert np.asarray(t_a.column("t_role_id")).tolist() != \
            np.asarray(t_b.column("t_role_id")).tolist()

    def test_written_table_param_site_never_amortizes(self):
        """A parameterized site over a table the program WRITES: the
        runtime refetches it every invocation, so (a) no diversity is
        observed there, (b) program_param_sites excludes its group, and
        (c) the cost model refuses amortization even when another program
        published a diversity for the same table group."""
        from repro.api import (CobraSession, ExecutionContext, StatsProfile,
                               program_param_sites)
        from repro.api.builder import col, param, q
        from repro.core import param_group_key

        def read_then_bump(worklist=()):
            out = []
            for wid in worklist:
                for r in q("roles").where(col("r_id")
                                          .eq(param("k"))).bind(k=wid):
                    out.append(r.r_rank)
                update_row("roles", "r_rank", 1, "r_id", wid)
            return out

        program = lift_program(read_then_bump)
        assert program_write_tables(program) == ("roles",)
        assert program_param_sites(program) == ()      # group excluded
        session = paper_session(make_wilos_db(100, ratio=10), FAST_LOCAL)
        exe = session.compile(program)
        batch = exe.run_batch([{"worklist": [1]}] * 3)
        assert batch.binding_observations == []        # nothing observed
        # a foreign published diversity for the roles group changes nothing
        ctx = ExecutionContext(batch_size=8, stats=StatsProfile.of(
            bindings={param_group_key(("roles",)): 0.01}))
        priced = session.compile(program, context=ctx)
        baseline = session.compile(program,
                                   context=ExecutionContext(batch_size=8))
        assert priced.est_cost_s == baseline.est_cost_s

    def test_cost_model_write_guard(self):
        from repro.api import ExecutionContext, StatsProfile
        from repro.core import CostModel, param_group_key
        from repro.relational.algebra import Cmp, Col, Param, Select
        db = make_wilos_db(100, ratio=10)
        cm = CostModel(db, CostCatalog(FAST_LOCAL), ExecutionContext(
            batch_size=8,
            stats=StatsProfile.of(bindings={param_group_key(("tasks",)):
                                            0.01})))
        pq = Select(Cmp("==", Col("t_role_id"), Param("r")), Scan("tasks"))
        assert cm.param_site_amortization(pq) == pytest.approx(1 / 8)
        cm.write_tables = frozenset(["tasks"])
        assert cm.param_site_amortization(pq) == 1.0
        assert not cm.tables_shareable(("tasks",))

    def test_saturated_site_freezes_fraction(self):
        """Past the distinct-tracking cap the fraction freezes at the
        estimate-so-far instead of decaying toward 0 as lookups keep
        coming."""
        import repro.runtime.sitecache as sc
        cache = SiteCache()
        q = Scan("tasks")
        old = sc._MAX_DISTINCT_TRACKED
        sc._MAX_DISTINCT_TRACKED = 4
        try:
            for i in range(4):                         # fully diverse
                cache.observe_binding(q, ("tasks",), ("k", i))
            (s,) = cache.site_binding_stats().values()
            assert s["fraction"] == pytest.approx(1.0)
            for i in range(100):                       # keep it diverse
                cache.observe_binding(q, ("tasks",), ("k", 1000 + i))
            (s,) = cache.site_binding_stats().values()
            assert s["fraction"] == pytest.approx(1.0)  # frozen, not 4/104
        finally:
            sc._MAX_DISTINCT_TRACKED = old


# --------------------------------------------------------------------------
# Binding observations reach BatchResult (feedback's input)
# --------------------------------------------------------------------------

class TestBindingObservations:
    def test_run_batch_reports_group_diversity(self):
        """The UNOPTIMIZED W_E executes one parameterized σ per worklist
        key: 3 lookups, 2 distinct bindings."""
        from repro.runtime import run_batch
        session = paper_session(make_wilos_db(200, ratio=10), FAST_LOCAL)
        batch = run_batch(session, make_wilos_e(),
                          [{"worklist": [1]}, {"worklist": [2]},
                           {"worklist": [1]}])
        # one observation per published granularity (qdiv + qprov), each
        # seeing the same 3 lookups / 2 distinct bindings
        obs = batch.binding_observations
        assert sorted(g.split(":")[0] for g, _, _ in obs) == ["qdiv", "qprov"]
        for _site, total, distinct in obs:
            assert total == 3 and distinct == 2

    def test_input_diversity_fallback_when_plan_has_no_param_sites(self):
        """The compiled (prefetch) W_E executes ZERO parameterized queries;
        the batch still reports the program-INPUT diversity for the source
        program's parameterized groups — this is what breaks the
        chicken-and-egg between running a binding-free plan and ever
        observing that bindings repeat."""
        session = paper_session(make_wilos_db(300, ratio=10))
        exe = session.compile(make_wilos_e())
        assert "prefetch" in repr(exe.program.body)
        batch = exe.run_batch([{"worklist": [1]}] * 4)
        obs = batch.binding_observations
        assert sorted(g.split(":")[0] for g, _, _ in obs) == ["qdiv", "qprov"]
        for _site, total, distinct in obs:
            assert total == 4 and distinct == 1

    def test_binding_free_program_reports_nothing(self):
        session = paper_session(make_orders_customer_db(100, 50))
        batch = session.compile(make_p0()).run_batch([{}] * 3)
        assert batch.binding_observations == []


# --------------------------------------------------------------------------
# Byte-budgeted eviction: approximate result sizes, LRU byte bound,
# oversize bypass
# --------------------------------------------------------------------------

class TestByteBudget:
    def _key(self, i):
        return (0, f"q{i}", (), ())

    def test_bytes_accounted_and_evicted_lru(self):
        from repro.runtime.sitecache import approx_result_bytes
        cache = SiteCache(max_bytes=1000, entry_max_bytes=1000)
        v = np.zeros(50, np.float64)             # 400 bytes via .nbytes
        assert approx_result_bytes(v) == 400
        cache.put(self._key(0), v, ("t",))
        cache.put(self._key(1), v, ("t",))
        assert cache.bytes_used == 800 and len(cache) == 2
        # third insert exceeds 1000: the LRU entry (key 0) is evicted
        cache.put(self._key(2), v, ("t",))
        assert cache.bytes_used == 800 and len(cache) == 2
        assert cache.get(self._key(0)) is None
        assert cache.get(self._key(2)) is not None
        assert cache.evictions == 1
        assert cache.stats()["bytes_used"] == 800
        assert cache.stats()["max_bytes"] == 1000

    def test_table_results_use_wire_bytes(self):
        from repro.runtime.sitecache import approx_result_bytes
        t = make_wilos_db(100, ratio=10).table("tasks")
        assert approx_result_bytes(t) == t.wire_bytes

    def test_oversize_result_bypasses_cache(self):
        cache = SiteCache(max_bytes=1000)        # entry cap defaults to 250
        big = np.zeros(100, np.float64)          # 800 bytes > 250
        cache.put(self._key(0), big, ("t",))
        assert len(cache) == 0 and cache.bytes_used == 0
        assert cache.oversize_bypasses == 1
        assert cache.stats()["oversize_bypasses"] == 1
        small = np.zeros(10, np.float64)         # 80 bytes: cached
        cache.put(self._key(1), small, ("t",))
        assert len(cache) == 1 and cache.bytes_used == 80

    def test_replace_and_invalidate_keep_accounting(self):
        cache = SiteCache(max_bytes=10_000)
        cache.put(self._key(0), np.zeros(10, np.float64), ("a",))
        cache.put(self._key(0), np.zeros(20, np.float64), ("a",))  # replace
        cache.put(self._key(1), np.zeros(10, np.float64), ("b",))
        assert cache.bytes_used == 160 + 80
        cache.invalidate_tables(["a"])
        assert cache.bytes_used == 80
        cache.clear()
        assert cache.bytes_used == 0

    def test_ttl_expiry_releases_bytes(self):
        clk = FakeClock()
        cache = SiteCache(ttl_s=5.0, max_bytes=10_000, clock=clk)
        cache.put(self._key(0), np.zeros(10, np.float64), ("t",))
        assert cache.bytes_used == 80
        clk.now = 6.0
        assert cache.get(self._key(0)) is None
        assert cache.bytes_used == 0

    def test_no_budget_means_no_sizing(self):
        cache = SiteCache()                      # default: entry bound only
        cache.put(self._key(0), np.zeros(1000, np.float64), ("t",))
        assert cache.get(self._key(0)) is not None
        assert cache.bytes_used == 0             # sizing skipped entirely

    def test_serving_runtime_threads_budget(self):
        session = paper_session(make_orders_customer_db(100, 20), FAST_LOCAL)
        rt = ServingRuntime(session, batch_size=4,
                            site_cache_max_bytes=1 << 20)
        assert rt.site_cache.max_bytes == 1 << 20
        rt.register(make_p0())
        rt.serve([("P0", {})] * 8)
        stats = rt.site_cache.stats()
        assert stats["bytes_used"] > 0
        assert stats["bytes_used"] <= 1 << 20


# --------------------------------------------------------------------------
# Oversize-entry spilling to the content-addressed disk tier
# --------------------------------------------------------------------------

class TestOversizeSpilling:
    """An oversize result spills to disk instead of bypassing: the round
    trip is still saved (spill_hits), while epoch keys, TTL, and eager
    invalidation govern the disk tier exactly like resident entries."""

    def _key(self, i=0):
        return ("origin", f"q{i}", (), (("t", 1, 1),))

    def _cache(self, tmp_path, **kw):
        kw.setdefault("entry_max_bytes", 256)
        return SiteCache(spill_dir=str(tmp_path / "spill"), **kw)

    def test_oversize_put_spills_and_serves_from_disk(self, tmp_path):
        cache = self._cache(tmp_path)
        big = np.arange(1000, dtype=np.float64)
        cache.put(self._key(), big, ("t",))
        s = cache.stats()
        assert s["spills"] == 1 and s["spilled_entries"] == 1
        assert s["entries"] == 0          # never admitted to memory
        assert len(list((tmp_path / "spill").iterdir())) == 1
        found = cache.lookup(self._key())
        assert found is not None
        value, crossed = found
        assert np.array_equal(value, big) and value.dtype == big.dtype
        assert crossed is False
        s = cache.stats()
        assert s["spill_hits"] == 1 and s["hits"] == 1

    def test_table_round_trips_bit_identical(self, tmp_path):
        cache = self._cache(tmp_path)
        t = make_wilos_db(200, ratio=10).table("tasks")
        cache.put(self._key(), t, ("tasks",))
        assert cache.stats()["spills"] == 1
        back = cache.get(self._key())
        assert back.name == t.name
        assert back.schema.names == t.schema.names
        for c in t.schema.names:
            a, b = np.asarray(t.column(c)), np.asarray(back.column(c))
            assert a.dtype == b.dtype and np.array_equal(a, b)

    def test_small_entries_stay_resident(self, tmp_path):
        cache = self._cache(tmp_path, max_bytes=1 << 20)
        cache.put(self._key(), np.zeros(4, np.float32), ("t",))
        s = cache.stats()
        assert s["entries"] == 1 and s["spills"] == 0

    def test_without_spill_dir_oversize_still_bypasses(self):
        cache = SiteCache(entry_max_bytes=256)
        cache.put(self._key(), np.arange(1000, dtype=np.float64), ("t",))
        s = cache.stats()
        assert s["oversize_bypasses"] == 1 and s["spills"] == 0
        assert cache.get(self._key()) is None

    def test_spilled_entries_honor_ttl(self, tmp_path):
        clk = FakeClock()
        cache = self._cache(tmp_path, ttl_s=5.0, clock=clk)
        cache.put(self._key(), np.arange(1000, dtype=np.float64), ("t",))
        clk.now = 6.0
        assert cache.get(self._key()) is None
        s = cache.stats()
        assert s["expirations"] == 1 and s["spill_hits"] == 0
        assert s["spilled_entries"] == 0  # index dropped with the file
        assert list((tmp_path / "spill").iterdir()) == []

    def test_invalidate_tables_unlinks_spilled_files(self, tmp_path):
        cache = self._cache(tmp_path)
        cache.put(self._key(0), np.arange(1000, dtype=np.float64), ("t",))
        cache.put(("o", "q_other", (), ()),
                  np.arange(1000, dtype=np.float64), ("other",))
        assert cache.invalidate_tables(["t"]) == 1
        assert cache.stats()["spilled_entries"] == 1
        assert len(list((tmp_path / "spill").iterdir())) == 1
        assert cache.get(self._key(0)) is None
        assert cache.get(("o", "q_other", (), ())) is not None

    def test_clear_drops_the_disk_tier(self, tmp_path):
        cache = self._cache(tmp_path)
        for i in range(3):
            cache.put(self._key(i), np.arange(1000, dtype=np.float64),
                      ("t",))
        cache.clear()
        assert cache.stats()["spilled_entries"] == 0
        assert list((tmp_path / "spill").iterdir()) == []

    def test_cross_era_spill_hit_counts_as_shared(self, tmp_path):
        cache = self._cache(tmp_path)
        cache.put(self._key(), np.arange(1000, dtype=np.float64), ("t",))
        cache.new_era()
        value, crossed = cache.lookup(self._key())
        assert crossed is True
        assert cache.stats()["shared_hits"] == 1

    def test_spill_failure_degrades_to_bypass(self, tmp_path):
        cache = self._cache(tmp_path)
        import shutil
        shutil.rmtree(tmp_path / "spill")   # yank the disk tier away
        cache.put(self._key(), np.arange(1000, dtype=np.float64), ("t",))
        s = cache.stats()
        assert s["oversize_bypasses"] == 1 and s["spills"] == 0
        assert cache.get(self._key()) is None

    def test_serving_runtime_threads_spill_dir(self, tmp_path):
        session = paper_session(make_orders_customer_db(400, 40), FAST_LOCAL)
        rt = ServingRuntime(session, batch_size=4,
                            site_cache=SiteCache(
                                entry_max_bytes=64,
                                spill_dir=str(tmp_path / "s")))
        rt.register(make_p0())
        rt.serve([("P0", {})] * 8)
        s = rt.site_cache.stats()
        # every site result is oversize for a 64-byte bound: all spilled,
        # and repeat batches hit the disk tier instead of the server
        assert s["spills"] >= 1
        assert s["spill_hits"] >= 1
        assert s["oversize_bypasses"] == 0
