"""Cobra core end-to-end: memo, rules, cost-based choice, codegen, semantics.

Reproduces the paper's qualitative claims as assertions:
  * P0 → P1 (join) at low Order cardinality, P0 → P2 (prefetch) when the
    join result dominates (Experiments 1–3), with the paper's rule subset;
  * Wilos patterns: Cobra ≥ heuristic ≥/≈ original (Experiment 4);
  * optimization time < 1 s (Sec. VIII);
  * cyclic rules terminate (T2 ↔ N2) via memo duplicate detection.
"""

import numpy as np
import pytest

from repro.core import CostCatalog, Interpreter, optimize
from repro.core.rules import default_rules
from repro.programs import (WILOS_PROGRAMS, make_m0, make_orders_customer_db,
                            make_p0, make_p1, make_p2, make_sales_db,
                            make_wilos_db)
from repro.relational.database import ClientEnv, FAST_LOCAL, SLOW_REMOTE


def run(prog, db, net, init=None):
    env = ClientEnv(db, net)
    out = Interpreter(env, "fast").run(prog, init)
    return out, env.clock


def coll_close(a, b, rtol=1e-4):
    a = np.sort(np.asarray(a, dtype=np.float64))
    b = np.sort(np.asarray(b, dtype=np.float64))
    return a.shape == b.shape and np.allclose(a, b, rtol=rtol)


def paper_rules():
    """Rule subset used in the paper's Experiments 1–3 (no T3 composition)."""
    return [r for r in default_rules() if r.name != "T3"]


class TestP0Alternatives:
    def test_picks_join_at_low_orders(self):
        db = make_orders_customer_db(100, 5000)
        res = optimize(make_p0(), db, CostCatalog(SLOW_REMOTE), rules=paper_rules())
        assert "JOIN" in repr(res.program.body)

    def test_picks_prefetch_when_join_dominates(self):
        db = make_orders_customer_db(4000, 500)
        res = optimize(make_p0(), db, CostCatalog(SLOW_REMOTE), rules=paper_rules())
        assert "prefetch" in repr(res.program.body)

    def test_optimized_semantics_match(self):
        db = make_orders_customer_db(500, 100)
        p0 = make_p0()
        o0, t0 = run(p0, db, SLOW_REMOTE)
        for rules in (paper_rules(), None):
            res = optimize(p0, db, CostCatalog(SLOW_REMOTE), rules=rules)
            o1, t1 = run(res.program, db, SLOW_REMOTE)
            assert coll_close(o0["result"], o1["result"])
            assert t1 <= t0

    def test_never_worse_than_original(self):
        # Sec VIII: "the program rewritten using COBRA always performs at
        # least as well as the original"
        for n_orders, n_cust in [(100, 2000), (1000, 1000), (3000, 300)]:
            db = make_orders_customer_db(n_orders, n_cust)
            p0 = make_p0()
            _, t_orig = run(p0, db, SLOW_REMOTE)
            res = optimize(p0, db, CostCatalog(SLOW_REMOTE))
            _, t_opt = run(res.program, db, SLOW_REMOTE)
            assert t_opt <= t_orig * 1.05

    def test_full_ruleset_beats_paper_alternatives(self):
        # beyond-paper: T3 ∘ T4j (projection-pushed join) beats P1 and P2
        db = make_orders_customer_db(2000, 500)
        res_full = optimize(make_p0(), db, CostCatalog(SLOW_REMOTE))
        _, t_full = run(res_full.program, db, SLOW_REMOTE)
        _, t_p1 = run(make_p1(), db, SLOW_REMOTE)
        _, t_p2 = run(make_p2(), db, SLOW_REMOTE)
        assert t_full <= min(t_p1, t_p2)


class TestDependentAggregations:
    def test_m0_kept_as_single_loop(self):
        """Sec. V-B: extracting `sum` to SQL adds a round trip; Cobra keeps
        the loop computing both sum and cumulative sum."""
        db = make_sales_db(5000)
        m0 = make_m0()
        o0, t0 = run(m0, db, SLOW_REMOTE)
        res = optimize(m0, db, CostCatalog(SLOW_REMOTE))
        o1, t1 = run(res.program, db, SLOW_REMOTE)
        assert abs(o0["total"] - o1["total"]) < 1e-2 * abs(o0["total"])
        assert {k: round(v, 1) for k, v in o0["cSum"].items()} == \
               {k: round(v, 1) for k, v in o1["cSum"].items()}
        assert t1 <= t0 * 1.05
        # exactly one query in the optimized program
        env = ClientEnv(db, SLOW_REMOTE)
        Interpreter(env, "fast").run(res.program)
        assert env.n_queries == 1


class TestWilosPatterns:
    @pytest.mark.parametrize("pid", list(WILOS_PROGRAMS))
    def test_cobra_at_least_as_good(self, pid):
        prog = WILOS_PROGRAMS[pid]()
        init = {"worklist": [1, 3, 5, 7]} if pid == "E" else None
        db = make_wilos_db(1000, ratio=10)
        o0, t_orig = run(prog, db, FAST_LOCAL, init)
        db2 = make_wilos_db(1000, ratio=10)
        res = optimize(prog, db2, CostCatalog(FAST_LOCAL, af=50.0))
        o1, t_opt = run(res.program, db2, FAST_LOCAL, init)
        for k in o0:
            if isinstance(o0[k], list):
                assert coll_close(o0[k], o1[k]), k
            elif isinstance(o0[k], (int, float)):
                assert abs(o0[k] - o1[k]) <= 1e-3 * max(1.0, abs(o0[k])), k
        if pid == "A":
            assert db.table("roles").same_rows(db2.table("roles"))
        assert t_opt <= t_orig * 1.05

    def test_pattern_a_cobra_prefetches_heuristic_pushes(self):
        db = make_wilos_db(1000)
        res_c = optimize(WILOS_PROGRAMS["A"](), db, CostCatalog(FAST_LOCAL))
        res_h = optimize(WILOS_PROGRAMS["A"](), db, CostCatalog(FAST_LOCAL),
                         choice="heuristic")
        assert "prefetch" in repr(res_c.program.body)
        assert "prefetch" not in repr(res_h.program.body)

    def test_pattern_b_cobra_keeps_single_query(self):
        db = make_wilos_db(1000)
        res_c = optimize(WILOS_PROGRAMS["B"](), db, CostCatalog(FAST_LOCAL))
        env = ClientEnv(db, FAST_LOCAL)
        Interpreter(env, "fast").run(res_c.program)
        assert env.n_queries == 1
        res_h = optimize(WILOS_PROGRAMS["B"](), db, CostCatalog(FAST_LOCAL),
                         choice="heuristic")
        env_h = ClientEnv(db, FAST_LOCAL)
        Interpreter(env_h, "fast").run(res_h.program)
        assert env_h.n_queries == 2  # count extracted to an extra SQL query

    def test_pattern_c_join_identified(self):
        db = make_wilos_db(1000)
        res = optimize(WILOS_PROGRAMS["C"](), db, CostCatalog(FAST_LOCAL))
        assert "JOIN" in repr(res.program.body)


class TestFramework:
    def test_optimization_time_under_1s(self):
        db = make_orders_customer_db(1000, 100)
        res = optimize(make_p0(), db, CostCatalog(SLOW_REMOTE))
        assert res.opt_time_s < 1.0

    def test_cyclic_rules_terminate(self):
        # T2c/N2c are mutually inverse; saturation must still stop
        db = make_wilos_db(500)
        res = optimize(WILOS_PROGRAMS["C"](), db, CostCatalog(FAST_LOCAL))
        assert res.memo_stats["rounds"] < 64
        assert res.memo_stats["duplicates_detected"] >= 1

    def test_already_optimal_input_unchanged_cost(self):
        # optimizing P2 should not make it slower
        db = make_orders_customer_db(2000, 200)
        p2 = make_p2()
        _, t0 = run(p2, db, SLOW_REMOTE)
        res = optimize(p2, db, CostCatalog(SLOW_REMOTE))
        _, t1 = run(res.program, db, SLOW_REMOTE)
        assert t1 <= t0 * 1.05
