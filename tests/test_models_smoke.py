"""Per-architecture smoke tests: reduced config, one forward + train step +
decode step on CPU; output shapes + no NaNs. Full configs are exercised only
via the dry-run (abstract lowering)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # full arch sweep (~2 min); excluded from test-fast

from repro.configs import ALL_ARCHS
from repro.models import (forward, get_arch, init_params, loss_fn, make_caches)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, T=16):
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    labels = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    b = {"labels": labels, "positions": pos}
    if cfg.enc_dec:
        b["tokens"] = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
        b["enc_embeds"] = jax.random.normal(KEY, (B, T, cfg.d_model), jnp.float32)
    elif cfg.frontend:
        b["embeds"] = jax.random.normal(KEY, (B, T, cfg.d_model), jnp.float32)
    else:
        b["tokens"] = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_and_finite(name):
    cfg = get_arch(name).scaled()
    params = init_params(KEY, cfg)
    b = _batch(cfg)
    inp = b["embeds"] if "embeds" in b else b["tokens"]
    logits, _, aux = forward(params, cfg, inp, b["positions"],
                             enc_inputs=b.get("enc_embeds"))
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_one_train_step_reduces_loss_direction(name):
    """One SGD step on the smoke config must produce a finite loss and
    finite grads for every parameter."""
    cfg = get_arch(name).scaled()
    params = init_params(KEY, cfg)
    b = _batch(cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, b)
    assert bool(jnp.isfinite(loss)), name
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))) for g in leaves)
    # apply a tiny step; loss must stay finite
    new_params = jax.tree_util.tree_map(
        lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    loss2 = loss_fn(new_params, cfg, b)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_step(name):
    cfg = get_arch(name).scaled()
    params = init_params(KEY, cfg)
    B = 2
    caches = make_caches(cfg, B, 32)
    tok = jax.random.randint(KEY, (B, 1), 0, cfg.vocab_size)
    pos = jnp.full((B, 1), 3)
    enc = jax.random.normal(KEY, (B, 16, cfg.d_model)) if cfg.enc_dec else None
    logits, new_caches, _ = forward(params, cfg, tok, pos, caches=caches,
                                    cache_index=3, enc_inputs=enc)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert new_caches is not None
    # cache structure preserved
    assert jax.tree_util.tree_structure(new_caches) == \
        jax.tree_util.tree_structure(caches)


@pytest.mark.parametrize("name", ["stablelm-12b", "h2o-danube-1.8b",
                                  "minicpm3-4b"])
def test_prefill_then_decode_matches_full_forward(name):
    """KV-cache correctness: decode token-by-token == full forward."""
    cfg = get_arch(name).scaled()
    params = init_params(KEY, cfg)
    B, T = 1, 8
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    full_logits, _, _ = forward(params, cfg, toks, pos)

    caches = make_caches(cfg, B, T, dtype=jnp.float32)
    outs = []
    for t in range(T):
        lg, caches, _ = forward(params, cfg, toks[:, t:t + 1],
                                pos[:, t:t + 1], caches=caches, cache_index=t)
        outs.append(lg[:, 0])
    step_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-2, atol=2e-2)


def test_sliding_window_masks_old_tokens():
    cfg = get_arch("h2o-danube-1.8b").scaled()
    import dataclasses
    cfg = dataclasses.replace(cfg, window=4)
    params = init_params(KEY, cfg)
    B, T = 1, 12
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    l1, _, _ = forward(params, cfg, toks, pos)
    # perturb a token far outside every later window; last logits unchanged
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    l2, _, _ = forward(params, cfg, toks2, pos)
    np.testing.assert_allclose(np.asarray(l1[0, -1], np.float32),
                               np.asarray(l2[0, -1], np.float32),
                               rtol=1e-3, atol=1e-3)


def test_moe_router_balance_loss_positive():
    cfg = get_arch("llama4-scout-17b-a16e").scaled()
    params = init_params(KEY, cfg)
    b = _batch(cfg)
    _, _, aux = forward(params, cfg, b["tokens"], b["positions"])
    assert float(aux) >= 1.0 - 1e-3  # Switch aux loss lower bound is 1 at balance


def test_param_counts_match_spec_order_of_magnitude():
    # full configs: sanity-check the parameter formulas
    expect = {"stablelm-12b": 12e9, "minicpm3-4b": 4e9, "h2o-danube-1.8b": 1.8e9,
              "internlm2-20b": 20e9, "rwkv6-3b": 3e9, "zamba2-1.2b": 1.2e9,
              "qwen2-vl-72b": 72e9, "llama4-scout-17b-a16e": 109e9,
              "kimi-k2-1t-a32b": 1.0e12}
    for name, want in expect.items():
        got = get_arch(name).n_params()
        assert 0.4 * want < got < 2.2 * want, (name, got, want)


def test_active_params_moe():
    kimi = get_arch("kimi-k2-1t-a32b")
    active = kimi.n_active_params()
    assert 20e9 < active < 60e9  # ~32B active
