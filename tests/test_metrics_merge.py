"""Multi-worker metrics merging (repro.obs.metrics).

``combine_snapshots`` is the unit a cluster folds per-worker registries
with, so its algebra has to be exact: associative, commutative, and
lossless (the combined dump equals the dump of one registry that observed
every worker's samples). The hypothesis property tests pin

    combine(a, combine(b, c)) == combine(combine(a, b), c)

over randomized registries with disjoint and overlapping label sets;
hypothesis is an optional dev dependency, so a seeded deterministic
generator runs the same properties in tier-1 regardless.
"""

import numpy as np
import pytest

from repro.obs.metrics import (MetricsRegistry, combine_snapshots,
                               merge_snapshots)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # optional dev dependency — see pyproject.toml
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------------
# deterministic registry generator (integer-valued, so every combine is
# bit-exact and associativity holds with == rather than approx)
# --------------------------------------------------------------------------

NAMES = ["requests", "hits", "evictions", "bytes", "spills"]
LABELS = [{}, {"worker": 0}, {"worker": 1}, {"table": "tasks"}]


def random_registry(rng) -> MetricsRegistry:
    reg = MetricsRegistry()
    for name in NAMES:
        for labels in LABELS:
            if rng.random() < 0.4:
                reg.inc(name, int(rng.integers(0, 1000)), **labels)
            if rng.random() < 0.3:
                reg.gauge("g_" + name, int(rng.integers(0, 1000)), **labels)
            for _ in range(int(rng.integers(0, 4))):
                reg.observe("h_" + name, int(rng.integers(-50, 50)),
                            **labels)
    return reg


def registries(seed, k=3):
    rng = np.random.default_rng(seed)
    return [random_registry(rng) for _ in range(k)]


class TestCombineSeeded:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
    def test_associative(self, seed):
        a, b, c = (r.dump() for r in registries(seed))
        left = combine_snapshots(combine_snapshots(a, b), c)
        right = combine_snapshots(a, combine_snapshots(b, c))
        assert left == right
        assert combine_snapshots(a, b, c) == left

    @pytest.mark.parametrize("seed", [10, 11, 12, 13])
    def test_commutative(self, seed):
        a, b = (r.dump() for r in registries(seed, k=2))
        assert combine_snapshots(a, b) == combine_snapshots(b, a)

    def test_identity(self):
        (a,) = (r.dump() for r in registries(99, k=1))
        empty = MetricsRegistry().dump()
        assert combine_snapshots(a, empty)["counters"] == a["counters"]
        assert combine_snapshots(a, empty)["hists"] == a["hists"]

    @pytest.mark.parametrize("seed", [20, 21, 22])
    def test_lossless_vs_single_registry(self, seed):
        # combining N dumps == one registry that saw every sample
        rng = np.random.default_rng(seed)
        samples = [(n, l, int(rng.integers(-100, 100)))
                   for n in NAMES for l in range(2)
                   for _ in range(int(rng.integers(1, 5)))]
        split = [MetricsRegistry() for _ in range(3)]
        whole = MetricsRegistry()
        for i, (name, lab, v) in enumerate(samples):
            split[i % 3].inc(name, v, worker=lab)
            split[i % 3].observe("h_" + name, v, worker=lab)
            whole.inc(name, v, worker=lab)
            whole.observe("h_" + name, v, worker=lab)
        combined = combine_snapshots(*(r.dump() for r in split))
        assert combined["counters"] == whole.dump()["counters"]
        assert combined["hists"] == whole.dump()["hists"]

    def test_disjoint_label_sets_union(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        a.inc("req", 3, worker=0)
        b.inc("req", 5, worker=1)
        out = combine_snapshots(a.dump(), b.dump())
        assert out["counters"] == {'req{worker=0}': 3, 'req{worker=1}': 5}

    def test_nonnumeric_gauges_first_wins(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        a.gauge("backend", "jax")
        b.gauge("backend", "numpy")
        b.gauge("entries", 7)
        out = combine_snapshots(a.dump(), b.dump())
        assert out["gauges"]["backend"] == "jax"
        assert out["gauges"]["entries"] == 7

    def test_flat_snapshot_rejected(self):
        reg = MetricsRegistry()
        reg.inc("x")
        reg.observe("lat", 1.0)
        with pytest.raises(TypeError):
            combine_snapshots(reg.dump(), reg.snapshot())

    def test_ingest_round_trips_structured_dump(self):
        src = registries(42, k=1)[0]
        dst = MetricsRegistry()
        dst.ingest(src.dump())
        assert dst.dump() == src.dump()
        # and ingesting a combined dump reproduces the combined registry
        a, b = (r.dump() for r in registries(43, k=2))
        agg = MetricsRegistry()
        agg.ingest(combine_snapshots(a, b))
        assert agg.dump() == combine_snapshots(a, b)

    def test_merge_hist_equals_observing_samples(self):
        xs = [3, -1, 4, 1, 5, -9, 2, 6]
        by_obs, by_merge = MetricsRegistry(), MetricsRegistry()
        for x in xs:
            by_obs.observe("lat", x)
        by_merge.merge_hist("lat", {"count": 3, "sum": sum(xs[:3]),
                                    "min": min(xs[:3]), "max": max(xs[:3])})
        by_merge.merge_hist("lat", {"count": 5, "sum": sum(xs[3:]),
                                    "min": min(xs[3:]), "max": max(xs[3:])})
        assert by_merge.histogram("lat") == by_obs.histogram("lat")
        by_merge.merge_hist("lat", {"count": 0, "sum": 99, "min": 0,
                                    "max": 0})   # empty hists are no-ops
        assert by_merge.histogram("lat") == by_obs.histogram("lat")

    def test_namespacing_merge_is_distinct_from_combine(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        a.inc("req", 2)
        b.inc("req", 3)
        named = merge_snapshots(w0=a.snapshot(), w1=b.snapshot())
        assert named == {"w0_req": 2, "w1_req": 3}


# --------------------------------------------------------------------------
# hypothesis property tests (skipped when hypothesis is not installed)
# --------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    label_sets = st.sampled_from([(), (("worker", 0),), (("worker", 1),),
                                  (("table", "tasks"), ("worker", 2))])

    @st.composite
    def registry_dumps(draw):
        reg = MetricsRegistry()
        for _ in range(draw(st.integers(0, 8))):
            name = draw(st.sampled_from(NAMES))
            labels = dict(draw(label_sets))
            kind = draw(st.integers(0, 2))
            v = draw(st.integers(-1000, 1000))
            if kind == 0:
                reg.inc(name, v, **labels)
            elif kind == 1:
                reg.gauge("g_" + name, v, **labels)
            else:
                reg.observe("h_" + name, v, **labels)
        return reg.dump()

    class TestCombineProperties:
        @settings(max_examples=200, deadline=None)
        @given(registry_dumps(), registry_dumps(), registry_dumps())
        def test_associative(self, a, b, c):
            assert combine_snapshots(a, combine_snapshots(b, c)) == \
                combine_snapshots(combine_snapshots(a, b), c)

        @settings(max_examples=200, deadline=None)
        @given(registry_dumps(), registry_dumps())
        def test_commutative(self, a, b):
            assert combine_snapshots(a, b) == combine_snapshots(b, a)

        @settings(max_examples=100, deadline=None)
        @given(registry_dumps())
        def test_empty_identity(self, a):
            out = combine_snapshots(a, MetricsRegistry().dump())
            assert out["counters"] == a["counters"]
            assert out["hists"] == a["hists"]
else:
    @pytest.mark.skip(reason="optional dev dependency (pip install "
                             "hypothesis) — see pyproject.toml")
    def test_hypothesis_properties():
        pass
