"""Golden parity: Pallas kernels (interpret mode) vs the numpy reference
path (``kernels/ref.py``) the jax-free compiled backend executes.

The compiled execution tier promises bit-identical results whichever
backend serves a columnar loop, so the kernels themselves must agree with
their numpy twins on exactly the shapes real plans produce: empty probe and
build sides, all-miss key sets, group counts above one tile, and skewed
segment sizes. Run with ``JAX_PLATFORMS=cpu`` in CI.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels import build_direct_table, join_probe, segment_reduce  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402

RNG = np.random.default_rng(11)


def direct(table_keys, key_space):
    return build_direct_table(jnp.asarray(table_keys, jnp.int32), key_space)


# --------------------------------------------------------------------------
# join_probe: Pallas kernel vs numpy twin
# --------------------------------------------------------------------------

class TestJoinProbeParity:
    def check(self, probe, build, key_space):
        probe = np.asarray(probe, np.int32)
        build = np.asarray(build, np.int32)
        got = np.asarray(join_probe(jnp.asarray(probe),
                                    direct(build, key_space),
                                    interpret=True))
        want = ref.join_probe_np(probe, build)
        np.testing.assert_array_equal(got, want)
        # and the jnp reference agrees with its numpy twin
        np.testing.assert_array_equal(
            np.asarray(ref.join_probe_ref(jnp.asarray(probe),
                                          jnp.asarray(build))), want)

    def test_empty_probe_side(self):
        self.check([], [3, 1, 4], 8)

    def test_empty_build_side(self):
        probe = np.asarray([0, 1, 2], np.int32)
        got = np.asarray(join_probe(jnp.asarray(probe),
                                    jnp.zeros((0,), jnp.int32),
                                    interpret=True))
        np.testing.assert_array_equal(got,
                                      ref.join_probe_np(probe, np.zeros(0)))
        assert (got == -1).all()

    def test_all_miss_keys(self):
        self.check([100, 200, 300, 7], [1, 2, 3], 512)

    def test_duplicate_probe_keys(self):
        self.check([2, 2, 5, 2, 5, 9], [9, 5, 2], 16)

    def test_random_sweep_past_one_block(self):
        build = RNG.permutation(4096)[:1500].astype(np.int32)
        probe = RNG.integers(0, 4096, size=3000).astype(np.int32)
        probe_j = jnp.asarray(probe)
        want = ref.join_probe_np(probe, build)
        got = np.asarray(join_probe(probe_j, direct(build, 4096),
                                    block_n=256, interpret=True))
        np.testing.assert_array_equal(got, want)
        hit = want >= 0
        assert hit.any() and (~hit).any()     # the sweep exercises both
        np.testing.assert_array_equal(build[want[hit]], probe[hit])


# --------------------------------------------------------------------------
# segment_reduce: Pallas kernel vs numpy twin
# --------------------------------------------------------------------------

class TestSegmentReduceParity:
    def check(self, values, segs, n_groups, op="sum", **kw):
        values = np.asarray(values, np.float32)
        segs = np.asarray(segs, np.int32)
        got = np.asarray(segment_reduce(jnp.asarray(values),
                                        jnp.asarray(segs), n_groups, op=op,
                                        interpret=True, **kw))
        want = ref.segment_reduce_np(values, segs, n_groups, op=op)
        np.testing.assert_allclose(got, want, rtol=0, atol=0)
        # the jnp oracle keeps jax's +-inf identity for empty min/max
        # groups; the kernel and its numpy twin map those to 0
        oracle = np.asarray(ref.segment_reduce_ref(jnp.asarray(values),
                                                   jnp.asarray(segs),
                                                   n_groups, op=op))
        oracle = np.where(np.isfinite(oracle), oracle, 0.0)
        np.testing.assert_allclose(oracle, want, rtol=0, atol=0)

    def test_empty_input(self):
        self.check([], [], 4)

    def test_zero_groups(self):
        self.check([], [], 0)

    def test_groups_above_one_tile(self):
        # 30 groups through a 8-wide group tile: 4 grid steps over groups
        segs = RNG.integers(0, 30, size=500)
        vals = RNG.integers(0, 9, size=500)
        self.check(vals, segs, 30, block_g=8, block_n=64)

    def test_skewed_segments(self):
        # one giant segment, several empty ones
        segs = np.zeros(1000, np.int32)
        segs[:3] = [7, 7, 3]
        vals = np.ones(1000)
        self.check(vals, segs, 8, block_n=128)

    @pytest.mark.parametrize("op", ["sum", "count", "min", "max"])
    def test_ops_with_empty_groups(self, op):
        segs = [0, 0, 2, 2, 2]          # group 1 and 3 empty
        vals = [3.0, -1.0, 5.0, 2.0, 2.0]
        self.check(vals, segs, 4, op=op, block_n=4, block_g=2)


# --------------------------------------------------------------------------
# ops dispatch: Pallas on/off must be value-identical
# --------------------------------------------------------------------------

class TestOpsDispatch:
    def test_equi_probe_pallas_toggle(self):
        probe = jnp.asarray(RNG.integers(0, 64, size=200), jnp.int32)
        build = jnp.asarray(RNG.permutation(64)[:40], jnp.int32)
        state = ops.pallas_state()
        try:
            ops.use_pallas(False)
            off = np.asarray(ops.equi_probe(probe, build, key_space=64))
            ops.use_pallas(True, interpret=True)
            on = np.asarray(ops.equi_probe(probe, build, key_space=64))
        finally:
            ops.use_pallas(state[0], interpret=state[1])
        np.testing.assert_array_equal(off, on)
        np.testing.assert_array_equal(
            off, ref.join_probe_np(np.asarray(probe), np.asarray(build)))

    def test_segment_reduce_pallas_toggle(self):
        vals = jnp.asarray(RNG.integers(0, 5, size=300), jnp.float32)
        segs = jnp.asarray(RNG.integers(0, 10, size=300), jnp.int32)
        state = ops.pallas_state()
        try:
            ops.use_pallas(False)
            off = np.asarray(ops.segment_reduce(vals, segs, 10))
            ops.use_pallas(True, interpret=True)
            on = np.asarray(ops.segment_reduce(vals, segs, 10))
        finally:
            ops.use_pallas(state[0], interpret=state[1])
        np.testing.assert_allclose(off, on, rtol=0, atol=0)

    def test_equi_probe_without_key_space_uses_ref(self):
        # no key_space -> no direct table; must still match the numpy twin
        probe = np.asarray([5, 1, 99, 1], np.int32)
        build = np.asarray([1, 5, 7], np.int32)
        got = np.asarray(ops.equi_probe(jnp.asarray(probe),
                                        jnp.asarray(build)))
        np.testing.assert_array_equal(got, ref.join_probe_np(probe, build))
