"""The statistics subsystem (repro.stats): histograms, selectivity,
q-error feedback.

Four acceptance properties, per the issue:

  * **merge algebra** — ``merge_histograms`` is associative, commutative
    and lossless (merged == built directly over the concatenated rows,
    bucket-for-bucket), so a sharded coordinator's merged statistics are
    bit-identical to the unsharded build. Property-tested with hypothesis
    when installed, and with a seeded deterministic generator regardless
    (the ``combine_snapshots`` pattern from test_metrics_merge.py).
  * **plan flip** — on the skewed ``events`` relation the histogram's
    ``param_eq_fraction`` (vs the scalar 1/NDV rule) flips the winning
    plan from per-key queries to a prefetch, and the outputs are
    bit-identical either way (integral payload — no float order effects).
  * **q-error feedback** — a stale histogram produces a large per-site
    q-error; the controller's targeted re-analyze rebuilds ONLY the
    drifted predicate column's histogram and the site's q-error drops
    back to ~1.
  * **single-fire** — drift + q-error triggers naming one table in a
    batch analyze once per (table, data epoch); repeats are deduped.
"""

import numpy as np
import pytest

from repro.api.session import CobraSession
from repro.cluster.database import ShardedDatabase
from repro.core import CostCatalog, LoopRegion, loop_site_key
from repro.core.context import ExecutionContext, StatsProfile
from repro.programs import make_skew_db, make_skew_probe, make_wilos_db
from repro.relational.algebra import Cmp, Col, Param, Scan, Select
from repro.relational.database import SLOW_REMOTE, DatabaseServer
from repro.runtime.feedback import FeedbackController
from repro.stats import (ColumnHistogram, StatsConfig, build_histogram,
                         merge_all, merge_histograms)
from repro.stats.qerror import QErrorTracker, q_error

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # optional dev dependency — see pyproject.toml
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------------
# deterministic column generator: mixed skew so MCV/bucket boundaries are
# actually exercised, integer-valued so every merge is bit-exact
# --------------------------------------------------------------------------

CFG = StatsConfig(n_buckets=8, n_mcv=4, sketch_k=64)


def random_column(rng, n=None) -> np.ndarray:
    n = int(rng.integers(0, 400)) if n is None else n
    if n == 0:
        return np.asarray([], dtype=np.int64)
    hot = rng.random()
    n_hot = int(n * hot)
    vals = np.concatenate([
        np.full(n_hot, int(rng.integers(0, 5)), dtype=np.int64),
        rng.integers(0, int(rng.integers(2, 60)), n - n_hot,
                     dtype=np.int64)])
    rng.shuffle(vals)
    return vals


def columns(seed, k=3):
    rng = np.random.default_rng(seed)
    return [random_column(rng) for _ in range(k)]


def hists_equal(a: ColumnHistogram, b: ColumnHistogram) -> bool:
    """Full structural equality: backbone, sketch, and every DERIVED
    summary (MCVs, equi-depth buckets, selectivity) bucket-for-bucket."""
    if a != b:            # backbone: values + counts + config
        return False
    if (a.sketch is None) != (b.sketch is None):
        return False
    if a.sketch is not None and not np.array_equal(a.sketch, b.sketch):
        return False
    am, bm = a.mcvs, b.mcvs
    if not (np.array_equal(am[0], bm[0]) and np.array_equal(am[1], bm[1])):
        return False
    for x, y in zip(a.buckets, b.buckets):
        if not np.array_equal(x, y):
            return False
    return (a.content_digest() == b.content_digest()
            and a.param_eq_fraction() == b.param_eq_fraction())


class TestMergeSeeded:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
    def test_associative(self, seed):
        a, b, c = (build_histogram(x, CFG) for x in columns(seed))
        left = merge_histograms(merge_histograms(a, b), c)
        right = merge_histograms(a, merge_histograms(b, c))
        assert hists_equal(left, right)
        assert hists_equal(merge_all([a, b, c]), left)

    @pytest.mark.parametrize("seed", [10, 11, 12, 13])
    def test_commutative(self, seed):
        a, b = (build_histogram(x, CFG) for x in columns(seed, k=2))
        assert hists_equal(merge_histograms(a, b), merge_histograms(b, a))

    @pytest.mark.parametrize("seed", [20, 21, 22, 23])
    def test_lossless_vs_direct_build(self, seed):
        """Merging per-part histograms == building one histogram over the
        concatenated rows — the property that makes a sharded
        coordinator's merged statistics trustworthy."""
        parts = columns(seed, k=4)
        merged = merge_all([build_histogram(p, CFG) for p in parts])
        direct = build_histogram(np.concatenate(parts), CFG)
        assert hists_equal(merged, direct)

    def test_empty_identity(self):
        (x,) = columns(99, k=1)
        h = build_histogram(x, CFG)
        e = build_histogram(np.asarray([], dtype=np.int64), CFG)
        assert hists_equal(merge_histograms(h, e), h)
        assert hists_equal(merge_histograms(e, h), h)

    def test_config_mismatch_rejected(self):
        a = build_histogram(np.asarray([1, 2]), CFG)
        b = build_histogram(np.asarray([1, 2]), StatsConfig(n_buckets=4))
        with pytest.raises(ValueError, match="config mismatch"):
            merge_histograms(a, b)

    def test_param_eq_fraction_uniform_equals_one_over_ndv(self):
        # exactly-uniform counts: Σ(f/N)² degenerates to 1/NDV, so the
        # histogram model agrees with the scalar rule on uniform data
        vals = np.repeat(np.arange(20), 50)
        h = build_histogram(vals, CFG)
        assert h.param_eq_fraction() == pytest.approx(1 / 20)

    def test_param_eq_fraction_skew(self):
        # 90% hot key: the self-join selectivity is dominated by hot²
        vals = np.concatenate([np.zeros(900, dtype=np.int64),
                               np.arange(1, 101, dtype=np.int64)])
        h = build_histogram(vals, CFG)
        assert h.param_eq_fraction() > 0.8
        assert h.param_eq_fraction() > 50 * (1.0 / h.ndv)


if HAVE_HYPOTHESIS:
    @st.composite
    def hist_columns(draw):
        n = draw(st.integers(0, 120))
        vals = draw(st.lists(st.integers(-50, 50), min_size=n, max_size=n))
        return np.asarray(vals, dtype=np.int64)

    class TestMergeProperties:
        @settings(max_examples=150, deadline=None)
        @given(hist_columns(), hist_columns(), hist_columns())
        def test_associative(self, x, y, z):
            a, b, c = (build_histogram(v, CFG) for v in (x, y, z))
            assert hists_equal(merge_histograms(merge_histograms(a, b), c),
                               merge_histograms(a, merge_histograms(b, c)))

        @settings(max_examples=150, deadline=None)
        @given(hist_columns(), hist_columns())
        def test_commutative_and_lossless(self, x, y):
            a, b = build_histogram(x, CFG), build_histogram(y, CFG)
            m = merge_histograms(a, b)
            assert hists_equal(m, merge_histograms(b, a))
            assert hists_equal(m, build_histogram(np.concatenate([x, y]),
                                                  CFG))
else:
    @pytest.mark.skip(reason="optional dev dependency (pip install "
                             "hypothesis) — see pyproject.toml")
    def test_hypothesis_properties():
        pass


# --------------------------------------------------------------------------
# Sharded coordinator stats == unsharded stats, bucket for bucket
# --------------------------------------------------------------------------

class TestShardedStats:
    @pytest.mark.parametrize("n_shards", [2, 4])
    def test_merged_stats_bit_identical(self, n_shards):
        base = make_skew_db(n=4000)
        sh = ShardedDatabase.shard(
            DatabaseServer(dict(base.tables), base.model), n_shards,
            keys={"events": "e_key"})
        assert base.stats_fingerprint(["events"]) == \
            sh.stats_fingerprint(["events"])
        for col in ("e_id", "e_key", "e_units"):
            assert hists_equal(base.stats("events").hist(col),
                               sh.stats("events").hist(col))

    def test_merged_stats_after_reanalyze(self):
        base = make_skew_db(n=2000)
        sh = ShardedDatabase.shard(
            DatabaseServer(dict(base.tables), base.model), 2,
            keys={"events": "e_key"})
        base.analyze("events")
        sh.analyze("events")
        assert base.stats_fingerprint(["events"]) == \
            sh.stats_fingerprint(["events"])
        assert hists_equal(base.stats("events").hist("e_key"),
                           sh.stats("events").hist("e_key"))

    def test_wilos_mixed_tables(self):
        src = make_wilos_db(1000, seed=5)
        base = DatabaseServer(dict(src.tables), src.model)
        sh = ShardedDatabase.shard(
            DatabaseServer(dict(src.tables), src.model), 2,
            keys={"tasks": "t_role_id"})
        assert base.stats_fingerprint(["tasks", "roles"]) == \
            sh.stats_fingerprint(["tasks", "roles"])


# --------------------------------------------------------------------------
# Acceptance: histogram selectivity flips the winning plan; outputs are
# bit-identical either way
# --------------------------------------------------------------------------

def _probe_loop_site():
    prog = make_skew_probe()

    def walk(r):
        if isinstance(r, LoopRegion):
            return r
        for c in r.children():
            f = walk(c)
            if f is not None:
                return f
    lp = walk(prog.body)
    return loop_site_key(lp.var, lp.source)


def _plan_kind(exe) -> str:
    body = repr(exe.program.body).lower()
    return "prefetch" if "prefetch" in body else "query"


class TestPlanFlip:
    @pytest.fixture(scope="class")
    def arms(self):
        ctx = ExecutionContext(
            batch_size=1, stats=StatsProfile.of({_probe_loop_site(): 4.0}))
        out = {}
        for name, cfg in [("hist", None),
                          ("scalar", StatsConfig(histograms=False))]:
            db = make_skew_db(stats_config=cfg)
            sess = CobraSession(db, CostCatalog(SLOW_REMOTE))
            out[name] = sess.compile(make_skew_probe(), context=ctx)
        return out

    def test_plans_differ(self, arms):
        # scalar 1/NDV prices a per-key probe at N/NDV = 400 rows, so 4
        # correlated fetches beat pulling all 20k rows; the histogram
        # knows the key is drawn from the skewed data itself (~16k rows
        # expected per probe), so the prefetch wins instead
        assert _plan_kind(arms["scalar"]) == "query"
        assert _plan_kind(arms["hist"]) == "prefetch"
        assert arms["scalar"].program.body.key() != \
            arms["hist"].program.body.key()

    def test_outputs_bit_identical_across_flip(self, arms):
        wl = [0, 3, 7, 11]
        r_scalar = arms["scalar"].run(worklist=wl).outputs["result"]
        r_hist = arms["hist"].run(worklist=wl).outputs["result"]
        assert r_scalar == r_hist
        assert len(r_scalar) > 18000            # hot key dominates
        assert all(isinstance(v, (int, np.integer)) for v in r_scalar)


# --------------------------------------------------------------------------
# q-error feedback: stale histogram -> targeted re-analyze -> q-error drops
# --------------------------------------------------------------------------

def _key_query():
    return Select(Cmp("==", Col("e_key"), Param("kid")), Scan("events"))


class TestQErrorFeedback:
    def _drifted_session(self):
        """Uniform data analyzed, then silently replaced by the skewed
        version (a bulk load nobody ran ANALYZE after): estimates for the
        hot key are ~45x off."""
        db = make_skew_db(hot=0.0, seed=7)
        skewed = make_skew_db(hot=0.9, seed=7)
        db.replace_table(skewed.table("events"))
        return CobraSession(db, CostCatalog(SLOW_REMOTE))

    def _observe_hot_key(self, session, fb):
        q = _key_query()
        result, _, _ = session.db.run(q, {"kid": 0})
        fb.observe([(q, result.nrows, 0.0)])
        return q.sql(), result.nrows

    def test_qerror_drops_after_targeted_reanalyze(self):
        session = self._drifted_session()
        fb = FeedbackController(session)
        sql, observed = self._observe_hot_key(session, fb)
        before = fb.qerrors.site(sql).last
        assert before > fb.drift_threshold          # stale stats flagged
        assert len(fb.events) == 1

        hb0 = session.db.histogram_builds
        fb.refresh(["events"])
        # targeted: ONLY the drifted predicate column's histogram rebuilt
        assert session.db.histogram_builds == hb0 + 1
        assert fb.analyzes_fired == 1

        _, after_rows = self._observe_hot_key(session, fb)
        after = fb.qerrors.site(sql).last
        assert after < 2.0 < before
        assert fb.qerrors.site(sql).worst == before

    def test_untracked_columns_keep_stale_histograms(self):
        session = self._drifted_session()
        fb = FeedbackController(session)
        self._observe_hot_key(session, fb)
        stale_units = session.db.stats("events").hist("e_units")
        fb.refresh(["events"])
        st = session.db.stats("events")
        # e_key rebuilt; e_units carried over from the stale build
        assert st.hist("e_units") is stale_units
        assert st.hist("e_key") is not None

    def test_single_fire_per_table_and_epoch(self):
        """Drift + q-error triggers both naming a table in one batch must
        analyze it once; repeats over unchanged data are deduped."""
        session = self._drifted_session()
        fb = FeedbackController(session)
        self._observe_hot_key(session, fb)
        fb.refresh(["events"])
        assert (fb.analyzes_fired, fb.analyzes_deduped) == (1, 0)
        # second trigger, same data epoch -> deduped, no analyze work
        hb = session.db.histogram_builds
        ver = session.db.stats_version
        fb.refresh(["events"])
        assert (fb.analyzes_fired, fb.analyzes_deduped) == (1, 1)
        assert session.db.histogram_builds == hb
        assert session.db.stats_version == ver
        # data changes -> the guard re-arms
        session.db.replace_table(make_skew_db(hot=0.5).table("events"))
        fb.refresh(["events"])
        assert (fb.analyzes_fired, fb.analyzes_deduped) == (2, 1)

    def test_qerror_in_stats_profile_but_not_fingerprint(self):
        session = self._drifted_session()
        fb = FeedbackController(session)
        sql, _ = self._observe_hot_key(session, fb)
        prof = fb.stats_profile()
        assert prof.qerror_for(sql) > fb.drift_threshold
        # q-error is published for observability, NOT plan identity —
        # keying plans on a value that moves every observation would
        # thrash exactly the caches re-analyze exists to protect
        with_qe = ExecutionContext(
            batch_size=1, stats=StatsProfile.of(qerrors={sql: 45.0}))
        bare = ExecutionContext(batch_size=1)
        assert with_qe.fingerprint() == bare.fingerprint()

    def test_qerror_surfaces_in_telemetry_and_triage(self):
        session = self._drifted_session()
        fb = FeedbackController(session)
        sql, _ = self._observe_hot_key(session, fb)
        tel = fb.telemetry()
        assert tel["qerror_sites"][sql]["worst"] > fb.drift_threshold
        assert tel["qerror_sites"][sql]["n"] == 1

        from repro.obs.triage import triage_fleet

        class _RT:
            pass
        rt = _RT()
        exe = session.compile(
            make_skew_probe(),
            context=ExecutionContext(
                batch_size=1,
                stats=StatsProfile.of({_probe_loop_site(): 4.0})))
        rt._programs = {"W_S": exe.source}
        rt._executables = {"W_S": exe}
        rt._requests_by_program = {"W_S": 5}
        rt.feedback = fb
        (row,) = triage_fleet(rt)
        assert row.qerror == fb.qerrors.site(sql).worst
        assert f"q-error {row.qerror:.1f}" in row.describe()

    def test_qerror_surfaces_in_explain(self):
        session = self._drifted_session()
        fb = FeedbackController(session)
        sql, _ = self._observe_hot_key(session, fb)
        exe = session.compile(
            make_skew_probe(),
            context=ExecutionContext(
                batch_size=1,
                stats=StatsProfile.of({_probe_loop_site(): 4.0})))
        from repro.obs.explain import explain_plan
        text = explain_plan(exe, feedback=fb)
        assert "tracked q-error" in text


# --------------------------------------------------------------------------
# the q-error metric itself
# --------------------------------------------------------------------------

class TestQErrorMetric:
    def test_symmetric_and_smoothed(self):
        assert q_error(10, 10) == 1.0
        assert q_error(10, 100) == q_error(100, 10)
        assert np.isfinite(q_error(0, 1000))
        assert q_error(0, 0) == 1.0

    def test_tracker_accounting(self):
        tr = QErrorTracker()
        tr.observe("s", 10, 10, tables=("events",))
        tr.observe("s", 10, 109)
        s = tr.site("s")
        assert s.n == 2
        assert s.last == pytest.approx(10.0)
        assert s.worst == pytest.approx(10.0)
        assert s.mean == pytest.approx(5.5)
        assert s.tables == ("events",)
        assert tr.latest() == {"s": s.last}
        assert tr.worst_sites()[0][0] == "s"
