"""Fault tolerance: checkpoint/restart, failure injection, data determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # end-to-end training runs; excluded from test-fast

from repro.checkpoint import Checkpointer
from repro.data import PipelineConfig, Prefetcher, SyntheticLM
from repro.launch.train import TrainConfig, train


def _leaves_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return all(np.array_equal(np.asarray(x, np.float32),
                              np.asarray(y, np.float32)) for x, y in zip(la, lb))


def test_loss_decreases_on_structured_stream(tmp_path):
    out = train(TrainConfig(arch="h2o-danube-1.8b", steps=60, global_batch=8,
                            seq_len=32, log_every=10))
    losses = [l for _, l in out["losses"]]
    # per-batch noise: require the best later loss to clearly beat the start
    assert min(losses[2:]) < losses[0] - 0.05, losses


def test_failure_injection_and_bitwise_resume(tmp_path):
    """Crash at step 7, restart, and land bit-identical to an uninterrupted
    run — checkpoint covers params, opt state, and the data cursor."""
    common = dict(arch="h2o-danube-1.8b", steps=12, global_batch=4,
                  seq_len=32, ckpt_every=5, log_every=100)
    ref = train(TrainConfig(**common, ckpt_dir=str(tmp_path / "ref")))

    crash_dir = str(tmp_path / "crash")
    with pytest.raises(RuntimeError, match="injected failure"):
        train(TrainConfig(**common, ckpt_dir=crash_dir, fail_at=7))
    resumed = train(TrainConfig(**common, ckpt_dir=crash_dir))
    assert resumed["final_step"] == 12
    assert _leaves_equal(ref["params"], resumed["params"])


def test_checkpoint_atomic_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))}}
    for step in (1, 2, 3):
        ck.save(step, tree, extras={"data": {"next_index": step}}, block=True)
    names = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert names == ["step_00000002", "step_00000003"]  # keep=2
    assert ck.latest_step() == 3
    step, restored, extras = ck.restore(tree)
    assert step == 3 and extras["data"]["next_index"] == 3
    assert _leaves_equal(tree, restored)


def test_checkpoint_restore_rejects_shape_mismatch(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.ones((4, 4))}, block=True)
    with pytest.raises(ValueError):
        ck.restore({"w": jnp.ones((5, 4))})


def test_pipeline_deterministic_and_shardable():
    cfg = PipelineConfig(global_batch=8, seq_len=16, vocab_size=100, seed=3)
    a = SyntheticLM(cfg).batch(5)
    b = SyntheticLM(cfg).batch(5)
    assert np.array_equal(a["tokens"], b["tokens"])
    # shards partition the global batch deterministically and disjointly
    s0 = SyntheticLM(PipelineConfig(global_batch=8, seq_len=16, vocab_size=100,
                                    seed=3, shard_rank=0, shard_count=2)).batch(5)
    s1 = SyntheticLM(PipelineConfig(global_batch=8, seq_len=16, vocab_size=100,
                                    seed=3, shard_rank=1, shard_count=2)).batch(5)
    assert s0["tokens"].shape == (4, 16) and s1["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_prefetcher_resume_state():
    cfg = PipelineConfig(global_batch=2, seq_len=8, vocab_size=50)
    src = SyntheticLM(cfg)
    p = Prefetcher(src, depth=2)
    first = p.get()
    st = p.state()
    p.close()
    p2 = Prefetcher.restore(src, st)
    nxt = p2.get()
    p2.close()
    assert np.array_equal(nxt["tokens"], src.batch(st["next_index"])["tokens"])


def test_gradient_compression_roundtrip():
    from repro.optim import compress_int8, compressed_accumulate, decompress_int8
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    q, s = compress_int8(g)
    assert q.dtype == jnp.int8
    err = jnp.max(jnp.abs(decompress_int8(q, s) - g))
    assert float(err) <= float(s) * 0.51 + 1e-6  # half-ulp of the int8 grid
    # error feedback drives the accumulated estimate toward the true sum
    acc = jnp.zeros_like(g)
    e = jnp.zeros_like(g)
    for _ in range(8):
        acc, e = compressed_accumulate(acc, g, e)
    rel = float(jnp.linalg.norm(acc - 8 * g) / jnp.linalg.norm(8 * g))
    assert rel < 0.01


def test_microbatched_step_matches_single_batch():
    """grad accumulation over microbatches == one big batch (linear loss)."""
    out1 = train(TrainConfig(arch="h2o-danube-1.8b", steps=3, global_batch=8,
                             seq_len=16, microbatch=1, log_every=1))
    out2 = train(TrainConfig(arch="h2o-danube-1.8b", steps=3, global_batch=8,
                             seq_len=16, microbatch=4, log_every=1))
    l1 = dict(out1["losses"])[3]
    l2 = dict(out2["losses"])[3]
    assert abs(l1 - l2) < 0.05, (l1, l2)
