"""Dry-run machinery on a small (8-device) mesh, via subprocess so the
XLA_FLAGS device-count override never leaks into this test session.

Validates:
  * lower+compile of train/decode steps on a 2×4 (data, model) mesh with
    fsdp_tp sharding for a reduced dense arch and a reduced MoE arch;
  * the two-point layer extrapolation against a fully-unrolled compile
    (exactness of the accounting methodology);
  * collective ops appear in the compiled HLO (the plan actually shards).
"""

import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # ~90s XLA compile fixture; excluded from test-fast

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import jax
import jax.numpy as jnp
from repro.models.arch import get_arch
from repro.launch.mesh import make_mesh
from repro.launch.sharding import make_policy
from repro.launch.specs import input_specs, make_optimizer, step_fn
from repro.analysis.roofline import collective_bytes_from_hlo
from repro.configs import SHAPES

# small shapes so compiles are fast
SHAPES["train_4k"] = dict(seq_len=128, global_batch=8, kind="train")
SHAPES["decode_32k"] = dict(seq_len=128, global_batch=8, kind="decode")

out = {}
mesh = make_mesh((2, 4), ("data", "model"))

def compile_cell(cfg, shape, kind, unroll):
    with mesh:
        pol = make_policy(mesh, strategy="fsdp_tp",
                          remat="full" if kind == "train" else "none",
                          microbatch=1, unroll_layers=unroll)
        opt = make_optimizer(cfg) if kind == "train" else None
        fn = step_fn(cfg, kind, pol, opt)
        args = input_specs(cfg, shape, pol, opt)
        compiled = jax.jit(fn).lower(*args.values()).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        coll = collective_bytes_from_hlo(compiled.as_text())
        return float(cost.get("flops", 0)), coll

for arch in ("h2o-danube-1.8b", "llama4-scout-17b-a16e"):
    base = get_arch(arch).scaled(n_layers=6, d_model=64, n_heads=4, d_ff=128,
                                 vocab=512)
    for shape, kind in (("train_4k", "train"), ("decode_32k", "decode")):
        f_full, coll = compile_cell(base, shape, kind, unroll=True)
        f2, _ = compile_cell(dataclasses.replace(base, n_layers=2), shape, kind, True)
        f4, _ = compile_cell(dataclasses.replace(base, n_layers=4), shape, kind, True)
        extrap = f2 + (6 - 2) * (f4 - f2) / 2
        out[f"{arch}/{shape}"] = {
            "flops_full": f_full, "flops_extrap": extrap,
            "rel_err": abs(extrap - f_full) / max(f_full, 1.0),
            "n_collectives": sum(coll["counts"].values()),
            "coll_types": sorted(coll["counts"]),
        }
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_cells_compile_and_shard(results):
    for tag, r in results.items():
        assert r["n_collectives"] > 0, f"{tag}: no collectives — not sharded?"


def test_two_point_extrapolation_exact(results):
    """Layer stacks are homogeneous ⇒ linear extrapolation must match the
    fully-unrolled compile closely. Tolerance 6%: at this toy scale the
    non-layer intercept (loss/optimizer fusion differences between
    compiles) is proportionally larger than at full scale, where layers
    dominate by orders of magnitude."""
    for tag, r in results.items():
        abs_err = abs(r["flops_extrap"] - r["flops_full"])
        # decode cells at toy scale have ~2M total FLOPs — fusion noise in
        # the intercept dominates; accept small absolute error there
        assert r["rel_err"] < 0.06 or abs_err < 1e6, (tag, r)


def test_expected_collective_types(results):
    train = results["h2o-danube-1.8b/train_4k"]
    assert any(t in train["coll_types"] for t in ("all-reduce", "all-gather",
                                                  "reduce-scatter"))
