"""Property-based tests (hypothesis) for system invariants.

  * fast (vectorized) interpreter ≡ exact interpreter: same output state AND
    same simulated clock, on randomized programs/data;
  * F-IR conversion ≡ direct loop execution;
  * every rule-produced alternative is semantics-preserving (the memo's
  	alternatives all compute the same transition);
  * join index machinery ≡ brute force.
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (pip install hypothesis) — see pyproject.toml")
from hypothesis import given, settings, strategies as st

from repro.core import CostCatalog, Interpreter, optimize
from repro.core.fir import eval_fir, loop_to_fir
from repro.core.regions import (Assign, CollectionAdd, CondRegion, IBin,
                                IConst, IEmptyList, IEmptyMap, IField,
                                ILoadAll, IVar, LoopRegion, MapPut, Program,
                                seq)
from repro.relational import (DatabaseServer, Field, Schema, Table,
                              equi_join_indices)
from repro.relational.database import ClientEnv, FAST_LOCAL, SLOW_REMOTE


# --------------------------------------------------------------------------
# data strategies
# --------------------------------------------------------------------------

@st.composite
def small_db(draw):
    n = draw(st.integers(1, 40))
    nk = draw(st.integers(1, 8))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    items = Table.from_columns(
        "items",
        Schema.of(Field("i_id", "int64", 8), Field("i_k", "int64", 8),
                  Field("i_v", "float32", 4), Field("i_w", "int32", 4)),
        i_id=np.arange(n), i_k=rng.integers(0, nk, n),
        i_v=rng.uniform(0, 10, n).astype(np.float32),
        i_w=rng.integers(0, 100, n))
    keys = Table.from_columns(
        "keys",
        Schema.of(Field("k_id", "int64", 8), Field("k_r", "int32", 4)),
        k_id=np.arange(nk), k_r=rng.integers(0, 5, nk))
    return DatabaseServer({"items": items, "keys": keys})


@st.composite
def accumulating_loop(draw):
    """A random cursor loop with 1–3 accumulators (incl. dependent/guarded)."""
    stmts = []
    outputs = []
    use_guard = draw(st.booleans())
    body = []
    if draw(st.booleans()):
        body.append(Assign("s", IBin("+", IVar("s"), IField(IVar("t"), "i_v"))))
        stmts.append(Assign("s", IConst(0.0)))
        outputs.append("s")
    if draw(st.booleans()):
        body.append(Assign("mx", IBin("max", IVar("mx"),
                                      IField(IVar("t"), "i_w"))))
        stmts.append(Assign("mx", IConst(0)))
        outputs.append("mx")
    body.append(CollectionAdd("out", IBin("*", IField(IVar("t"), "i_v"),
                                          IConst(2.0))))
    stmts.append(Assign("out", IEmptyList()))
    outputs.append("out")
    if draw(st.booleans()) and "s" in outputs:
        body.append(MapPut("m", IField(IVar("t"), "i_k"), IVar("s")))
        stmts.append(Assign("m", IEmptyMap()))
        outputs.append("m")
    inner = seq(*body)
    if use_guard:
        inner = CondRegion(IBin("<", IField(IVar("t"), "i_w"), IConst(50)), inner)
    loop = LoopRegion("t", ILoadAll("items"), inner)
    return Program("rand", seq(*stmts, loop), tuple(outputs))


def _state_close(a, b):
    for k in a:
        va, vb = a[k], b[k]
        if isinstance(va, list):
            assert len(va) == len(vb)
            assert np.allclose(np.asarray(va, np.float64),
                               np.asarray(vb, np.float64), rtol=1e-4, atol=1e-4), k
        elif isinstance(va, dict):
            assert set(va) == set(vb)
            for kk in va:
                assert abs(float(va[kk]) - float(vb[kk])) < 1e-3 * max(1, abs(float(va[kk]))), k
        elif isinstance(va, (int, float)):
            assert abs(float(va) - float(vb)) <= 1e-3 * max(1.0, abs(float(va))), k


# --------------------------------------------------------------------------
# properties
# --------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(db=small_db(), prog=accumulating_loop())
def test_fast_interpreter_equals_exact(db, prog):
    env1 = ClientEnv(db, SLOW_REMOTE)
    o1 = Interpreter(env1, "exact").run(prog)
    env2 = ClientEnv(db, SLOW_REMOTE)
    o2 = Interpreter(env2, "fast").run(prog)
    _state_close(o1, o2)
    assert abs(env1.clock - env2.clock) < 1e-9 + 1e-6 * env1.clock
    assert env1.n_queries == env2.n_queries


@settings(max_examples=40, deadline=None)
@given(db=small_db(), prog=accumulating_loop())
def test_fir_fold_equals_loop(db, prog):
    loop = prog.body.parts[-1]
    try:
        fold, idx = loop_to_fir(loop)
    except Exception:
        return  # not all random loops are representable; that's fine
    import copy
    env1 = ClientEnv(db, SLOW_REMOTE)
    exact = Interpreter(env1, "exact")
    state = {}
    for p in prog.body.parts[:-1]:
        exact.exec_region(p, state)
    init_state = copy.deepcopy(state)
    exact.exec_region(loop, state)
    env2 = ClientEnv(db, SLOW_REMOTE)
    vals = eval_fir(fold, env2, init_state)
    got = {v: vals[i] for v, i in idx.items()}
    _state_close({k: state[k] for k in got}, got)


@settings(max_examples=25, deadline=None)
@given(db=small_db(), prog=accumulating_loop(), slow=st.booleans())
def test_optimizer_preserves_semantics_and_cost(db, prog, slow):
    net = SLOW_REMOTE if slow else FAST_LOCAL
    env0 = ClientEnv(db, net)
    o0 = Interpreter(env0, "fast").run(prog)
    res = optimize(prog, db, CostCatalog(net))
    env1 = ClientEnv(db, net)
    o1 = Interpreter(env1, "fast").run(res.program)
    _state_close(o0, o1)
    assert env1.clock <= env0.clock * 1.2 + 1e-6


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 8), max_size=30),
       st.lists(st.integers(0, 8), max_size=30))
def test_join_indices_match_bruteforce(lk, rk):
    lk = np.asarray(lk, dtype=np.int64)
    rk = np.asarray(rk, dtype=np.int64)
    li, ri = equi_join_indices(lk, rk)
    got = sorted(zip(li.tolist(), ri.tolist()))
    want = sorted((i, j) for i in range(len(lk)) for j in range(len(rk))
                  if lk[i] == rk[j])
    assert got == want


@settings(max_examples=20, deadline=None)
@given(db=small_db())
def test_memo_alternatives_all_equivalent(db):
    """Every alternative in the expanded Region DAG computes the same state."""
    from repro.core.dag import expand
    from repro.core.rules import RuleContext, build_memo, default_rules
    from repro.core.search import Searcher, plan_to_region, hoist_prefetches
    from repro.core.cost import CostModel

    prog = Program("m", seq(
        Assign("s", IConst(0.0)),
        Assign("out", IEmptyList()),
        LoopRegion("t", ILoadAll("items"), seq(
            Assign("s", IBin("+", IVar("s"), IField(IVar("t"), "i_v"))),
            CollectionAdd("out", IField(IVar("t"), "i_w")),
        ))), ("s", "out"))
    env0 = ClientEnv(db, FAST_LOCAL)
    o0 = Interpreter(env0, "exact").run(prog)

    ctx = RuleContext(db=db)
    memo, root = build_memo(prog, ctx)
    expand(memo, default_rules(), ctx)
    cm = CostModel(db, CostCatalog(FAST_LOCAL))
    searcher = Searcher(memo, cm, ctx)
    plans = searcher.group_plans(root)
    assert plans
    for plan in plans:  # each top-K alternative must be equivalent
        region = hoist_prefetches(plan_to_region(plan))
        alt = Program("alt", region, prog.outputs)
        env1 = ClientEnv(db, FAST_LOCAL)
        o1 = Interpreter(env1, "exact").run(alt)
        _state_close(o0, o1)
