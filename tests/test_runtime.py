"""The serving runtime: batched execution, persistent plan store, feedback.

Issue acceptance:
  * ``run_batch`` over N parameter sets issues ONE server round trip per
    query site per batch (round-trip counter) and matches per-invocation
    ``run()`` results bit-for-bit;
  * a second ``CobraSession`` pointed at the same ``PlanStore`` directory
    reports a cache hit without running the memo search;
  * per-table stats versions: ``analyze()`` of an unrelated table keeps
    plans alive, the touched table invalidates;
  * feedback-triggered recompilation picks a different winner after the
    data drifts.
"""

import numpy as np
import pytest

from repro.api import (CobraSession, OptimizerConfig, program_tables,
                       query_tables)
from repro.core import CostCatalog
from repro.programs import (make_m0, make_orders_customer_db, make_p0,
                            make_sales_db, make_wilos_a, make_wilos_b,
                            make_wilos_db, make_wilos_e, make_wilos_f)
from repro.relational.database import FAST_LOCAL, SLOW_REMOTE
from repro.runtime import (BatchResult, FeedbackController, PlanStore,
                           ServingRuntime, program_has_updates, run_batch,
                           serve)


def paper_session(db, network=SLOW_REMOTE):
    return CobraSession(db, CostCatalog(network),
                        config=OptimizerConfig.preset("paper-exp1-3"))


# --------------------------------------------------------------------------
# run_batch
# --------------------------------------------------------------------------

class TestRunBatch:
    def test_batch_matches_per_invocation_bit_for_bit(self):
        session = paper_session(make_orders_customer_db(500, 100))
        exe = session.compile(make_p0())
        single = exe.run()
        batch = exe.run_batch([{}] * 6)
        assert batch.batched and len(batch) == 6
        for r in batch.results:
            assert r.outputs == single.outputs       # exact, not approx

    def test_batch_with_varying_params_matches_run(self):
        session = paper_session(make_wilos_db(400, ratio=10), FAST_LOCAL)
        exe = session.compile(make_wilos_e())
        param_sets = [{"worklist": [1, 3]}, {"worklist": [2]},
                      {"worklist": [1, 3]}, {"worklist": []}]
        batch = exe.run_batch(param_sets)
        for p, r in zip(param_sets, batch.results):
            assert r.outputs == exe.run(**p).outputs

    def test_one_round_trip_per_query_site_per_batch(self):
        """The acceptance counter: N invocations share each query site's
        single server round trip."""
        n = 8
        session = paper_session(make_orders_customer_db(500, 100))
        exe = session.compile(make_p0())
        sites = exe.run().n_round_trips          # sites fetched by ONE run
        batch = exe.run_batch([{}] * n)
        assert batch.n_round_trips == sites      # not n * sites
        assert batch.site_hits == (n - 1) * sites
        # two independent query sites (W_F: two narrow scans) -> two trips
        sf = paper_session(make_wilos_db(300), FAST_LOCAL)
        exe_f = sf.compile(make_wilos_f())
        bf = exe_f.run_batch([{}] * 5)
        assert bf.n_round_trips == exe_f.run().n_round_trips

    def test_distinct_bindings_fetch_distinct_sites(self):
        """A query site bound to different parameters is a different fetch;
        identical bindings reuse the batch's site cache. (The UNOPTIMIZED
        W_E issues one σ query per worklist key — the optimized form
        prefetches the whole relation into a single site.)"""
        session = paper_session(make_wilos_db(400, ratio=10), FAST_LOCAL)
        batch = run_batch(session, make_wilos_e(),
                          [{"worklist": [1]}, {"worklist": [2]},
                           {"worklist": [1]}])
        per_worklist = session.execute(make_wilos_e(),
                                       worklist=[1]).n_round_trips
        # keys 1 and 2 each fetched once; the repeated worklist [1] is a
        # pure site-cache reuse
        assert batch.n_round_trips == 2 * per_worklist
        assert batch.site_hits >= 1
        # and the optimized form collapses to ONE site for the whole batch
        exe = session.compile(make_wilos_e())
        opt = exe.run_batch([{"worklist": [1]}, {"worklist": [2]}])
        assert opt.n_round_trips == 1

    def test_bulk_navigation_single_round_trip(self):
        """The vectorize.py extension: the UNOPTIMIZED N+1 program's
        navigation site fetches all missing keys in one combined trip."""
        db = make_orders_customer_db(400, 80)
        session = paper_session(db)
        exact = session.execute(make_p0())       # N+1: one trip per miss
        batch = run_batch(session, make_p0(), [{}] * 3)
        assert exact.n_round_trips > 50
        # loadAll(orders) + one bulk navigation fetch for the whole batch
        assert batch.n_round_trips == 2
        assert batch.results[0].outputs == exact.outputs
        assert batch.simulated_s < exact.simulated_s

    def test_update_program_falls_back_to_sequential(self):
        session = paper_session(make_wilos_db(200), FAST_LOCAL)
        assert program_has_updates(make_wilos_a())
        exe = session.compile(make_wilos_a())
        batch = exe.run_batch([{}] * 2)
        assert not batch.batched and len(batch) == 2

    def test_unknown_param_rejected(self):
        session = paper_session(make_orders_customer_db(50, 50))
        exe = session.compile(make_p0())
        with pytest.raises(TypeError, match="unknown program input"):
            exe.run_batch([{"nope": 1}])

    def test_site_cache_key_is_full_content(self):
        """Array-valued bindings are keyed by full content (repr truncates
        large arrays and would collide); unrepresentable values bypass the
        cache instead of risking a stale hit."""
        from repro.runtime.batch import _Uncacheable, _param_key
        a = np.arange(2000)
        b = a.copy()
        b[1000] = -1
        assert repr(a) == repr(b)                       # the trap
        assert _param_key({"k": a}) != _param_key({"k": b})
        assert _param_key({"k": a}) == _param_key({"k": a.copy()})
        with pytest.raises(_Uncacheable):
            _param_key({"k": object()})

    def test_batch_result_telemetry_sums(self):
        session = paper_session(make_orders_customer_db(200, 100))
        batch = session.compile(make_p0()).run_batch([{}] * 4)
        assert isinstance(batch, BatchResult)
        assert batch.simulated_s == pytest.approx(
            sum(r.simulated_s for r in batch.results))
        assert batch.n_round_trips == sum(r.n_round_trips for r in batch.results)
        assert "batched" in batch.describe()


# --------------------------------------------------------------------------
# PlanStore
# --------------------------------------------------------------------------

class TestPlanStore:
    def test_cross_session_hit_skips_memo_search(self, tmp_path):
        """Acceptance: session B on the same store dir compiles without a
        memo run and reports the hit through telemetry."""
        store_dir = str(tmp_path / "plans")
        sa = CobraSession(make_orders_customer_db(100, 5000),
                          CostCatalog(SLOW_REMOTE),
                          config=OptimizerConfig.preset("paper-exp1-3"),
                          plan_store=store_dir)
        ea = sa.compile(make_p0())
        assert not ea.from_cache and sa.memo_runs == 1
        assert sa.telemetry["store_puts"] == 1

        sb = CobraSession(make_orders_customer_db(100, 5000),
                          CostCatalog(SLOW_REMOTE),
                          config=OptimizerConfig.preset("paper-exp1-3"),
                          plan_store=store_dir)
        eb = sb.compile(make_p0())
        assert eb.from_cache and sb.memo_runs == 0
        assert sb.telemetry["store_hits"] == 1
        # identical plan artifact: same winner, same cost, same emitted IR
        assert eb.est_cost_s == ea.est_cost_s
        assert eb.program.body.key() == ea.program.body.key()
        # and the restored plan actually executes
        out = eb.run()
        base = sb.compile(make_p0()).run()
        assert out.outputs == base.outputs

    def test_stale_entry_not_served_after_data_change(self, tmp_path):
        """Store validity is judged by statistics CONTENT: a session whose
        stats genuinely differ (data changed + analyzed) must not be served
        the old plan."""
        store_dir = str(tmp_path / "plans")
        sa = CobraSession(make_orders_customer_db(100, 500),
                          CostCatalog(SLOW_REMOTE), plan_store=store_dir)
        sa.compile(make_p0())
        sb = CobraSession(make_orders_customer_db(100, 500),
                          CostCatalog(SLOW_REMOTE), plan_store=store_dir)
        grown = make_orders_customer_db(4000, 500)
        sb.db.add_table(grown.table("orders"))    # new data + fresh stats
        eb = sb.compile(make_p0())
        assert not eb.from_cache and sb.memo_runs == 1
        assert sb.plan_store.stale >= 1

    def test_restart_with_extra_analyzes_still_warm(self, tmp_path):
        """Version counters are process-local; a 'restarted' session whose
        counters diverge (extra analyze() calls on byte-equal data) still
        warm-starts, because the store compares stats content."""
        store_dir = str(tmp_path / "plans")
        sa = CobraSession(make_orders_customer_db(100, 500),
                          CostCatalog(SLOW_REMOTE), plan_store=store_dir)
        sa.analyze()                              # counters out of sync
        sa.analyze()
        sa.compile(make_p0())
        sb = CobraSession(make_orders_customer_db(100, 500),
                          CostCatalog(SLOW_REMOTE), plan_store=store_dir)
        eb = sb.compile(make_p0())                # same stats content
        assert eb.from_cache and sb.memo_runs == 0

    def test_distinct_configs_distinct_entries(self, tmp_path):
        store = PlanStore(str(tmp_path / "plans"))
        s = CobraSession(make_orders_customer_db(100, 500),
                         CostCatalog(SLOW_REMOTE),
                         config=OptimizerConfig.preset("paper-exp1-3"),
                         plan_store=store)
        s.compile(make_p0())
        s.compile(make_p0(), config=OptimizerConfig.preset("full"))
        assert len(store) == 2
        assert len(store.index()) == 2

    def test_corrupt_entry_degrades_to_miss(self, tmp_path):
        import os
        store = PlanStore(str(tmp_path / "plans"))
        s = CobraSession(make_sales_db(200), CostCatalog(SLOW_REMOTE),
                         plan_store=store)
        s.compile(make_m0())
        (plan_file,) = [n for n in os.listdir(store.root)
                        if n.endswith(".plan")]
        with open(os.path.join(store.root, plan_file), "wb") as f:
            f.write(b"not a pickle")
        s2 = CobraSession(make_sales_db(200), CostCatalog(SLOW_REMOTE),
                          plan_store=store)
        exe = s2.compile(make_m0())              # recovers by recompiling
        assert not exe.from_cache and store.errors >= 1

    def test_clear_and_stats_shape(self, tmp_path):
        store = PlanStore(str(tmp_path / "plans"))
        assert set(store.stats()) == {"entries", "hits", "misses", "stale",
                                      "puts", "races", "gc_evictions",
                                      "errors"}
        s = CobraSession(make_sales_db(100), CostCatalog(SLOW_REMOTE),
                         plan_store=store)
        s.compile(make_m0())
        assert len(store) == 1
        store.clear()
        assert len(store) == 0


# --------------------------------------------------------------------------
# Per-table stats versions
# --------------------------------------------------------------------------

class TestPerTableStatsVersions:
    def test_unrelated_analyze_keeps_plan_alive(self):
        """W_B touches only `tasks`; re-analyzing `roles` must not evict it."""
        session = paper_session(make_wilos_db(400), FAST_LOCAL)
        assert program_tables(make_wilos_b()) == ("tasks",)
        session.compile(make_wilos_b())
        session.analyze("roles")
        assert session.compile(make_wilos_b()).from_cache
        session.analyze("tasks")
        exe = session.compile(make_wilos_b())
        assert not exe.from_cache and session.memo_runs == 2

    def test_global_analyze_still_invalidates(self):
        session = paper_session(make_orders_customer_db(100, 500))
        session.compile(make_p0())
        session.analyze()
        assert not session.compile(make_p0()).from_cache

    def test_table_versions_move_independently(self):
        db = make_wilos_db(100)
        v_roles, v_tasks = db.table_version("roles"), db.table_version("tasks")
        db.analyze("roles")
        assert db.table_version("roles") == v_roles + 1
        assert db.table_version("tasks") == v_tasks
        assert db.stats_token(["roles", "tasks"]) == (
            ("roles", v_roles + 1), ("tasks", v_tasks))

    def test_replace_table_leaves_stats_stale(self):
        db = make_orders_customer_db(100, 100)
        v = db.table_version("orders")
        est_before = db.stats("orders").nrows
        db.replace_table(make_orders_customer_db(4000, 100).table("orders"))
        assert db.table_version("orders") == v       # no ANALYZE ran
        assert db.stats("orders").nrows == est_before
        assert db.table("orders").nrows == 4000      # but the data moved


# --------------------------------------------------------------------------
# Feedback-driven re-optimization
# --------------------------------------------------------------------------

class TestFeedback:
    def _drifted_session(self):
        """Compile against 100 orders / 5000 customers, then bulk-load the
        4000/500 profile WITHOUT analyze — estimates are now badly stale."""
        db = make_orders_customer_db(100, 5000)
        session = paper_session(db)
        grown = make_orders_customer_db(4000, 500)
        return session, grown

    def test_controller_detects_cardinality_drift(self):
        session, grown = self._drifted_session()
        exe = session.compile(make_p0())
        session.db.replace_table(grown.table("orders"))
        session.db.replace_table(grown.table("customer"))
        batch = exe.run_batch([{}] * 2)
        fb = FeedbackController(session, drift_threshold=3.0)
        drifted = fb.observe(batch.observations)
        assert "orders" in drifted
        assert fb.events and fb.events[0].ratio > 3.0
        assert fb.telemetry()["drift_events"] >= 1

    def test_serving_recompile_picks_different_winner(self):
        """Acceptance: drift -> re-analyze -> recompile flips P1 join to
        P2 prefetch, mid-stream, without touching unrelated plans."""
        session, grown = self._drifted_session()
        rt = ServingRuntime(session, batch_size=4, drift_threshold=3.0)
        rt.register(make_p0())
        assert "JOIN" in repr(rt.executable("P0").program.body)

        session.db.replace_table(grown.table("orders"))
        session.db.replace_table(grown.table("customer"))
        responses = rt.serve([("P0", {})] * 8)
        assert all(r is not None for r in responses)
        assert rt.recompiles >= 1
        assert "prefetch" in repr(rt.executable("P0").program.body)
        # the recompiled plan still computes the right answer
        base = session.execute(make_p0())
        final = rt.executable("P0").run()
        assert (np.sort(np.asarray(final["result"], dtype=np.float64))
                == pytest.approx(np.sort(np.asarray(base["result"],
                                                    dtype=np.float64)),
                                 rel=1e-4))

    def test_no_drift_no_recompile(self):
        session = paper_session(make_orders_customer_db(200, 100))
        rt = ServingRuntime(session, batch_size=4)
        rt.register(make_p0())
        rt.serve([("P0", {})] * 8)
        assert rt.recompiles == 0 and rt.feedback.refreshes == 0

    def test_unrelated_program_stays_hot_through_drift(self):
        """M0 (sales) keeps its cached plan while orders/customer drift."""
        db = make_orders_customer_db(100, 5000)
        sales = make_sales_db(300)
        db.add_table(sales.table("sales"))
        session = paper_session(db)
        rt = ServingRuntime(session, batch_size=4, drift_threshold=3.0)
        rt.register(make_p0())
        rt.register(make_m0())
        memo_after_register = session.memo_runs

        grown = make_orders_customer_db(4000, 500)
        session.db.replace_table(grown.table("orders"))
        session.db.replace_table(grown.table("customer"))
        rt.serve([("P0", {}), ("M0", {})] * 3)
        assert rt.recompiles >= 1
        # only P0 recompiled; M0's plan (sales only) never re-ran the memo
        assert session.memo_runs == memo_after_register + rt.recompiles
        # ...and stays hot under the serving context it was compiled for
        # (a one-shot compile would be a DIFFERENT plan request: plans are
        # keyed by ExecutionContext, batch amortization may change winners)
        assert session.compile(make_m0(),
                               context=rt.current_context()).from_cache

    def test_serve_preserves_request_order_across_programs(self):
        db = make_orders_customer_db(100, 50)
        db.add_table(make_sales_db(100).table("sales"))
        session = paper_session(db)
        responses, rt = serve(session, [make_p0(), make_m0()],
                              [("P0", {}), ("M0", {}), ("P0", {})],
                              batch_size=2)
        assert len(responses) == 3
        assert "result" in responses[0] and "total" in responses[1]
        assert rt.requests_served == 3

    def test_query_tables_helper(self):
        from repro.api import q
        h = q("orders").join("customer", "o_customer_sk", "c_customer_sk")
        assert query_tables(h.query) == ("customer", "orders")


# --------------------------------------------------------------------------
# Plan-store cold-compile race + GC bound
# --------------------------------------------------------------------------

class TestPlanStoreRaceAndGC:
    def _session(self, store, n_orders=100, n_cust=5000):
        return CobraSession(make_orders_customer_db(n_orders, n_cust),
                            CostCatalog(SLOW_REMOTE),
                            config=OptimizerConfig.preset("paper-exp1-3"),
                            plan_store=store)

    def test_cold_compile_race_first_writer_wins(self, tmp_path):
        """Two sessions racing on the same cold program both run the memo
        search, but the second put() re-reads instead of overwriting: it
        returns (and serves) the first writer's canonical result."""
        store = PlanStore(str(tmp_path / "plans"))
        sa = self._session(store)
        result_a = sa.compile(make_p0()).result
        assert store.puts == 1

        # simulate the loser of the race: a second session that missed the
        # store read (compiled concurrently) and now writes its own result
        sb = CobraSession(make_orders_customer_db(100, 5000),
                          CostCatalog(SLOW_REMOTE),
                          config=OptimizerConfig.preset("paper-exp1-3"))
        result_b = sb.compile(make_p0()).result
        assert result_b is not result_a

        key = sa._cache_key(make_p0(), sa.catalog, sa.config, None)
        fp = sa.db.stats_fingerprint(program_tables(make_p0()))
        canonical = store.put(key, result_b, stats_fp=fp)
        # first writer won: the caller gets A's stored artifact (a fresh
        # unpickle of it), not its own freshly-compiled result
        assert canonical is not result_b
        assert canonical.program.body.key() == result_a.program.body.key()
        assert canonical.est_cost == result_a.est_cost
        assert store.races == 1 and store.puts == 1  # nothing overwritten

    def test_stale_entry_still_superseded(self, tmp_path):
        """First-writer-wins only applies to entries valid for the caller's
        statistics; a stale entry is replaced as before."""
        store = PlanStore(str(tmp_path / "plans"))
        sa = self._session(store)
        sa.compile(make_p0())
        grown = make_orders_customer_db(4000, 500)
        sa.db.replace_table(grown.table("orders"))
        sa.db.replace_table(grown.table("customer"))
        sa.analyze()
        exe = sa.compile(make_p0())
        assert not exe.from_cache
        assert store.puts == 2 and store.races == 0

    def test_max_entries_gc_drops_least_recently_used(self, tmp_path):
        import os
        import time
        store = PlanStore(str(tmp_path / "plans"), max_entries=2)
        db = make_orders_customer_db(100, 200)
        db.add_table(make_sales_db(100).table("sales"))
        wil = make_wilos_db(100)
        db.add_table(wil.table("tasks"))
        db.add_table(wil.table("roles"))
        session = CobraSession(db, CostCatalog(SLOW_REMOTE),
                               config=OptimizerConfig.preset("paper-exp1-3"),
                               plan_store=store)

        session.compile(make_p0())
        session.compile(make_m0())
        assert len(store) == 2 and store.gc_evictions == 0
        # age the entries apart, then touch P0's via a get (LRU refresh)
        paths = sorted(os.path.join(store.root, n)
                       for n in os.listdir(store.root) if n.endswith(".plan"))
        now = time.time()
        for i, p in enumerate(paths):
            os.utime(p, (now - 100 + i, now - 100 + i))
        key = session._cache_key(make_p0(), session.catalog, session.config,
                                 None)
        fp = session.db.stats_fingerprint(program_tables(make_p0()))
        assert store.get(key, stats_fp=fp) is not None

        session.compile(make_wilos_b())       # third program -> GC fires
        assert len(store) == 2
        assert store.gc_evictions == 1
        # P0 (recently touched) survived; M0 (least recently used) did not
        assert store.get(key, stats_fp=fp) is not None
        m0_key = session._cache_key(make_m0(), session.catalog,
                                    session.config, None)
        m0_fp = session.db.stats_fingerprint(program_tables(make_m0()))
        assert store.get(m0_key, stats_fp=m0_fp) is None
        assert len(store.index()) == 2        # sidecar pruned with the plans

    def test_max_entries_validation(self, tmp_path):
        with pytest.raises(ValueError, match="max_entries"):
            PlanStore(str(tmp_path / "plans"), max_entries=0)


# --------------------------------------------------------------------------
# PlanStore: max_entries GC racing concurrent put()s
# --------------------------------------------------------------------------

class TestPlanStoreGCvsConcurrentPuts:
    """The first-writer-wins put() path interleaved with another store's
    mtime-LRU GC on the same directory (two serving processes sharing a
    bounded store)."""

    @staticmethod
    def _key(fp, stats=1):
        from repro.api import PlanCacheKey
        return PlanCacheKey(program_fp=fp, catalog_key=("cat",),
                            config_key=("cfg",), stats_version=stats)

    def test_gc_evicting_entry_between_puts_recreates_it(self, tmp_path):
        """Writer A stores k1; writer B (bounded) stores k2 and its GC
        drops k1. A's next put of k1 must see a cold store — a fresh write,
        NOT a race — and the entry must be readable again."""
        import os
        import time
        root = str(tmp_path / "plans")
        a = PlanStore(root)
        b = PlanStore(root, max_entries=1)
        a.put(self._key("p1"), "plan-1")
        # age k1 so B's GC deterministically picks it as LRU
        p1_path = a._path(a.logical_key(self._key("p1")))
        os.utime(p1_path, (time.time() - 100, time.time() - 100))
        b.put(self._key("p2"), "plan-2")
        assert b.gc_evictions == 1 and not os.path.exists(p1_path)

        out = a.put(self._key("p1"), "plan-1-recompiled")
        assert out == "plan-1-recompiled"
        assert a.races == 0 and a.puts == 2          # fresh write, no race
        assert a.get(self._key("p1")) == "plan-1-recompiled"

    def test_first_writer_wins_survives_gc_pressure(self, tmp_path):
        """A racing second writer is discarded (first-writer-wins) and the
        canonical entry — its mtime refreshed by the winning get()s — stays
        resident through a bounded writer's GC while a colder entry is
        evicted instead."""
        import os
        import time
        root = str(tmp_path / "plans")
        a = PlanStore(root)
        b = PlanStore(root, max_entries=2)
        a.put(self._key("hot"), "canonical")
        a.put(self._key("cold"), "cold-plan")
        now = time.time()
        for fp, age in (("hot", 50), ("cold", 90)):
            p = a._path(a.logical_key(self._key(fp)))
            os.utime(p, (now - age, now - age))

        # the race: B compiled "hot" concurrently and tries to store its own
        assert b.put(self._key("hot"), "duplicate") == "canonical"
        assert b.races == 1 and b.puts == 0
        # B's get refreshes the canonical entry's LRU recency...
        assert b.get(self._key("hot")) == "canonical"
        # ...so a third entry's GC evicts "cold", never the raced-on entry
        b.put(self._key("third"), "plan-3")
        assert b.gc_evictions == 1
        assert a.get(self._key("hot")) == "canonical"
        assert a.get(self._key("cold")) is None      # miss: GC'd
        assert a.misses == 1

    def test_get_survives_file_vanishing_after_exists_check(self, tmp_path,
                                                            monkeypatch):
        """A concurrent GC may unlink the entry between _load's exists()
        check and the open() — that window must degrade to a cold miss,
        not an exception."""
        import os
        store = PlanStore(str(tmp_path / "plans"))
        store.put(self._key("p"), "plan")
        path = store._path(store.logical_key(self._key("p")))
        os.unlink(path)                              # the GC "wins"
        monkeypatch.setattr(os.path, "exists",
                            lambda p: True if p == path else
                            os.path.lexists(p))
        assert store.get(self._key("p")) is None
        assert store.misses == 1 and store.errors == 0

    def test_sequential_put_get_interleaving_converges(self, tmp_path):
        """Many writers on one bounded directory: every surviving entry is
        readable, counters are consistent, and the store never exceeds its
        bound after any put."""
        root = str(tmp_path / "plans")
        stores = [PlanStore(root, max_entries=3) for _ in range(3)]
        # "a" repeats while still resident (a race), then again after a GC
        # evicted it (a fresh write); distinct keys keep the GC firing
        sequence = ["a", "b", "c", "a", "d", "e", "a", "f", "b"]
        for i, fp in enumerate(sequence):
            s = stores[i % 3]
            s.put(self._key(fp, stats=1), f"plan-{fp}")
            assert len(s) <= 3
        for s in stores:
            for fp in "abcdef":
                got = s.get(self._key(fp, stats=1))
                assert got is None or got == f"plan-{fp}"
        # at least one raced (repeat while resident) and the GC fired
        assert sum(s.races for s in stores) >= 1
        assert sum(s.gc_evictions for s in stores) >= 1
        assert all(s.errors == 0 for s in stores)


# --------------------------------------------------------------------------
# Feedback: observed while/collection-loop iteration counts
# --------------------------------------------------------------------------

class TestIterationObservations:
    def _scan_setup(self):
        from repro.programs import make_scan
        session = paper_session(make_wilos_db(200, ratio=10))
        return session, session.compile(make_scan())

    def test_run_batch_logs_while_iterations(self):
        from repro.core import while_site_key, WhileRegion
        session, exe = self._scan_setup()

        def find_while(r):
            if isinstance(r, WhileRegion):
                return r
            for c in r.children():
                w = find_while(c)
                if w is not None:
                    return w

        site = while_site_key(find_while(exe.source.body).pred)
        batch = exe.run_batch([{"threshold": 1e9}] * 3)
        counts = [n for s, n in batch.iteration_observations if s == site]
        assert counts == [5, 5, 5]     # max_state=5, threshold never crossed

    def test_controller_records_iterations_in_telemetry(self):
        """Satellite acceptance: the controller records per-site iteration
        counts — and they survive in telemetry — independent of whether any
        recompile consumes them."""
        session, exe = self._scan_setup()
        fb = FeedbackController(session)
        batch = exe.run_batch([{"threshold": 1e9}] * 2)
        fb.observe_iterations(batch.iteration_observations)
        t = fb.telemetry()
        (site_stats,) = t["iteration_sites"].values()
        assert site_stats["n"] == 2
        assert site_stats["avg_iters"] == pytest.approx(5.0)
        assert site_stats["published"] == pytest.approx(5.0)
        assert t["iters_publishes"] == 1

    def test_publish_hysteresis(self):
        """Small fluctuations never move the published value (stable plan
        keys); a real shift re-publishes."""
        session, _ = self._scan_setup()
        fb = FeedbackController(session)
        assert fb.observe_iterations([("loop:site", 10)])          # first
        assert not fb.observe_iterations([("loop:site", 11)])      # in band
        profile = fb.stats_profile()
        assert profile.iters_for("loop:site") == pytest.approx(10.0)
        # sustained growth pushes the running mean out of the band
        assert fb.observe_iterations([("loop:site", 100)] * 10)
        assert fb.stats_profile().iters_for("loop:site") > 50

    def test_worklist_loop_length_recorded(self):
        from repro.core import loop_site_key, LoopRegion
        session = paper_session(make_wilos_db(200, ratio=10))
        exe = session.compile(make_wilos_e())

        def find_loop(r):
            if isinstance(r, LoopRegion):
                return r
            for c in r.children():
                w = find_loop(c)
                if w is not None:
                    return w

        site = loop_site_key(find_loop(exe.source.body).var,
                             find_loop(exe.source.body).source)
        batch = exe.run_batch([{"worklist": [1, 2, 3]}])
        assert (site, 3) in batch.iteration_observations

    def test_sequential_fallback_still_records_iterations(self):
        """Mutating programs run the isolated sequential path — their
        iteration observations must reach the feedback loop all the same."""
        from repro.api import lift_program
        from repro.api.lift import update_row
        from repro.core import loop_site_key, LoopRegion

        def f(worklist=()):
            for wid in worklist:
                update_row("roles", "r_rank", 1, "r_id", wid)

        session = paper_session(make_wilos_db(100, ratio=10))
        exe = session.compile(lift_program(f))
        batch = exe.run_batch([{"worklist": [1, 2, 3, 4]}])
        assert not batch.batched                 # update -> sequential path
        loop = exe.source.body
        while not isinstance(loop, LoopRegion):
            loop = loop.children()[0]
        site = loop_site_key(loop.var, loop.source)
        assert (site, 4) in batch.iteration_observations

    def test_publish_threshold_validation(self):
        session, _ = self._scan_setup()
        with pytest.raises(ValueError, match="iters_publish_threshold"):
            FeedbackController(session, iters_publish_threshold=1.0)


# --------------------------------------------------------------------------
# Feedback: wall-clock drift (observed time vs estimated plan cost)
# --------------------------------------------------------------------------

class TestWallClockDrift:
    def _session(self):
        return paper_session(make_sales_db(500))

    def test_wall_clock_drift_flags_tables(self):
        """Row counts match the estimate exactly, but observed execution
        time is far off the modeled query cost -> wall_clock drift event."""
        from repro.core.cost import CostModel
        from repro.relational.algebra import Scan
        session = self._session()
        fb = FeedbackController(session, drift_threshold=3.0,
                                cost_drift_threshold=5.0)
        query = Scan("sales")
        est_rows = session.db.estimate(query).n_rows
        est_s = CostModel(session.db, session.catalog).query_cost(query)
        drifted = fb.observe([(query, int(est_rows), est_s * 20.0)])
        assert drifted == ["sales"]
        (event,) = fb.events
        assert event.kind == "wall_clock"
        assert event.ratio == pytest.approx(20.0, rel=1e-6)
        assert event.est_s == pytest.approx(est_s)
        assert "wall-clock" in event.describe()
        assert fb.telemetry()["drift_events_wall_clock"] == 1

    def test_in_band_wall_clock_is_quiet(self):
        from repro.core.cost import CostModel
        from repro.relational.algebra import Scan
        session = self._session()
        fb = FeedbackController(session, drift_threshold=3.0,
                                cost_drift_threshold=5.0)
        query = Scan("sales")
        est_rows = session.db.estimate(query).n_rows
        est_s = CostModel(session.db, session.catalog).query_cost(query)
        assert fb.observe([(query, int(est_rows), est_s * 1.5)]) == []
        assert not fb.events

    def test_row_drift_takes_precedence_no_double_event(self):
        from repro.relational.algebra import Scan
        session = self._session()
        fb = FeedbackController(session, drift_threshold=3.0,
                                cost_drift_threshold=5.0)
        query = Scan("sales")
        est_rows = session.db.estimate(query).n_rows
        drifted = fb.observe([(query, int(est_rows) * 10, 1e9)])
        assert drifted == ["sales"]
        assert len(fb.events) == 1 and fb.events[0].kind == "rows"

    def test_wall_clock_drift_disabled_with_none(self):
        from repro.relational.algebra import Scan
        session = self._session()
        fb = FeedbackController(session, drift_threshold=3.0,
                                cost_drift_threshold=None)
        query = Scan("sales")
        est_rows = session.db.estimate(query).n_rows
        assert fb.observe([(query, int(est_rows), 1e9)]) == []

    def test_threshold_validation(self):
        session = self._session()
        with pytest.raises(ValueError, match="cost_drift_threshold"):
            FeedbackController(session, cost_drift_threshold=0.5)

    def test_serving_runtime_plumbs_cost_threshold(self):
        session = self._session()
        rt = ServingRuntime(session, cost_drift_threshold=7.0)
        assert rt.feedback.cost_drift_threshold == 7.0
        rt2 = ServingRuntime(session, cost_drift_threshold=None)
        assert rt2.feedback.cost_drift_threshold is None
