"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (build_direct_table, flash_attention, join_probe,
                           rwkv6_scan, segment_reduce)
from repro.kernels import ref

KEY = jax.random.PRNGKey(7)


def keys(n):
    return jax.random.split(KEY, n)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

ATTN_SWEEP = [
    # (B, H, KV, Tq, Tk, hd, dtype, causal, window, chunk)
    (1, 2, 2, 64, 64, 32, jnp.float32, True, None, None),
    (2, 4, 2, 64, 64, 16, jnp.float32, True, None, None),     # GQA
    (1, 2, 1, 128, 128, 32, jnp.bfloat16, True, None, None),  # bf16 + GQA
    (1, 2, 2, 64, 64, 32, jnp.float32, True, 16, None),       # SWA
    (1, 2, 2, 64, 64, 32, jnp.float32, True, None, 32),       # chunked local
    (1, 1, 1, 32, 128, 32, jnp.float32, True, None, None),    # decode-ish tail
    (1, 2, 2, 64, 64, 64, jnp.float32, False, None, None),    # bidirectional
]


@pytest.mark.slow  # full attention sweep; excluded from test-fast
@pytest.mark.parametrize("B,H,KV,Tq,Tk,hd,dt,causal,window,chunk", ATTN_SWEEP)
def test_flash_attention_matches_ref(B, H, KV, Tq, Tk, hd, dt, causal,
                                     window, chunk):
    k1, k2, k3 = keys(3)
    q = jax.random.normal(k1, (B, H, Tq, hd), dt)
    k = jax.random.normal(k2, (B, KV, Tk, hd), dt)
    v = jax.random.normal(k3, (B, KV, Tk, hd), dt)
    got = flash_attention(q, k, v, causal=causal, window=window, chunk=chunk,
                          block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   chunk=chunk)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.slow
def test_flash_attention_block_shape_independence():
    k1, k2, k3 = keys(3)
    q = jax.random.normal(k1, (1, 2, 128, 32))
    k = jax.random.normal(k2, (1, 2, 128, 32))
    v = jax.random.normal(k3, (1, 2, 128, 32))
    outs = [flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
            for bq, bk in [(32, 32), (64, 32), (32, 64), (128, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# rwkv6 scan
# --------------------------------------------------------------------------

RWKV_SWEEP = [
    # (B, H, T, K, V, chunk, dtype)
    (1, 2, 64, 16, 16, 16, jnp.float32),
    (2, 3, 128, 32, 32, 32, jnp.float32),
    (1, 2, 64, 16, 32, 64, jnp.float32),    # chunk == T
    (1, 2, 96, 16, 16, 32, jnp.bfloat16),
]


@pytest.mark.slow  # full scan sweep; excluded from test-fast
@pytest.mark.parametrize("B,H,T,K,V,chunk,dt", RWKV_SWEEP)
def test_rwkv6_scan_matches_ref(B, H, T, K, V, chunk, dt):
    k1, k2, k3, k4, k5 = keys(5)
    r = jax.random.normal(k1, (B, H, T, K), dt)
    k = jax.random.normal(k2, (B, H, T, K), dt)
    v = jax.random.normal(k3, (B, H, T, V), dt)
    w = -jnp.exp(jax.random.normal(k4, (B, H, T, K)) * 1.5).astype(jnp.float32)
    u = jax.random.normal(k5, (H, K), jnp.float32)
    y, s = rwkv6_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    y0, s0 = ref.rwkv6_scan_ref(r, k, v, w, u)
    tol = 3e-2 if dt == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y0, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s0),
                               rtol=1e-3, atol=1e-3)


def test_rwkv6_extreme_decay_stable():
    """Strongly negative decays must underflow benignly, never overflow."""
    k1, k2, k3 = keys(3)
    B, H, T, K = 1, 1, 64, 16
    r = jax.random.normal(k1, (B, H, T, K))
    k = jax.random.normal(k2, (B, H, T, K))
    v = jax.random.normal(k3, (B, H, T, K))
    w = jnp.full((B, H, T, K), -40.0)
    u = jnp.zeros((H, K))
    y, s = rwkv6_scan(r, k, v, w, u, chunk=16, interpret=True)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.all(jnp.isfinite(s)))


# --------------------------------------------------------------------------
# segment reduce (relational γ)
# --------------------------------------------------------------------------

SEG_SWEEP = [
    (100, 7, "sum"), (512, 64, "sum"), (1000, 13, "count"),
    (257, 5, "min"), (300, 999, "max"), (64, 1, "sum"),
]


@pytest.mark.parametrize("N,G,op", SEG_SWEEP)
def test_segment_reduce_matches_ref(N, G, op):
    k1, k2 = keys(2)
    vals = jax.random.normal(k1, (N,), jnp.float32)
    segs = jax.random.randint(k2, (N,), 0, G)
    got = segment_reduce(vals, segs, G, op=op, block_n=64, block_g=128,
                         interpret=True)
    want = ref.segment_reduce_ref(vals, segs, G, op=op)
    # empty groups: kernel emits 0 for min/max; align oracle
    if op in ("min", "max"):
        counts = ref.segment_reduce_ref(jnp.ones_like(vals), segs, G, "sum")
        want = jnp.where(counts > 0, want, 0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# join probe
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n_build,n_probe,space", [
    (50, 200, 64), (1000, 333, 1024), (7, 1500, 8),
])
def test_join_probe_matches_ref(n_build, n_probe, space):
    rng = np.random.default_rng(0)
    build = jnp.asarray(rng.choice(space, size=n_build, replace=False)
                        .astype(np.int32))
    probe = jnp.asarray(rng.integers(0, space, n_probe).astype(np.int32))
    table = build_direct_table(build, space)
    got = join_probe(probe, table, block_n=128, interpret=True)
    want = ref.join_probe_ref(probe, build)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_join_probe_roundtrip_semantics():
    """probe→gather reproduces the relational equi-join."""
    rng = np.random.default_rng(1)
    build = jnp.asarray(np.arange(100, dtype=np.int32))
    payload = jnp.asarray(rng.normal(size=100).astype(np.float32))
    probe = jnp.asarray(rng.integers(0, 100, 400).astype(np.int32))
    table = build_direct_table(build, 128)
    idx = join_probe(probe, table, interpret=True)
    joined = payload[idx]
    np.testing.assert_allclose(np.asarray(joined),
                               np.asarray(payload)[np.asarray(probe)])
