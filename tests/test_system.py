"""End-to-end system behaviour: the full Cobra pipeline + planner + serving.

(The original placeholder file; now the top-level integration tests.)
"""

import numpy as np
import pytest

from repro.core import CostCatalog, Interpreter, optimize
from repro.core.planner import MeshShape, PlanChoice, TPUCostModel, plan
from repro.models.arch import get_arch
from repro.programs import make_orders_customer_db, make_p0
from repro.relational.database import ClientEnv, SLOW_REMOTE


def test_full_cobra_pipeline_p0():
    """program → region DAG → rules → cost search → codegen → execution."""
    db = make_orders_customer_db(500, 200)
    p0 = make_p0()
    res = optimize(p0, db, CostCatalog(SLOW_REMOTE))
    assert res.opt_time_s < 1.0
    assert res.memo_stats["and_nodes"] > 5
    env0, env1 = ClientEnv(db, SLOW_REMOTE), ClientEnv(db, SLOW_REMOTE)
    o0 = Interpreter(env0, "fast").run(p0)
    o1 = Interpreter(env1, "fast").run(res.program)
    assert o0["result"] == o1["result"]
    assert env1.clock < env0.clock


class TestPlanner:
    def test_every_arch_shape_has_feasible_plan(self):
        from repro.configs import ALL_ARCHS, SHAPES
        for arch in ALL_ARCHS:
            cfg = get_arch(arch)
            for shape, spec in SHAPES.items():
                if shape == "long_500k" and not cfg.subquadratic:
                    continue
                out = plan(cfg, spec["seq_len"], spec["global_batch"],
                           spec["kind"], mesh=(1, 16, 16))
                assert out["terms"]["feasible"], (arch, shape, out["choice"])

    def test_moe_prefers_all_to_all_for_many_experts(self):
        """T4 analogue: 384 experts × top-8 must batch into all_to_all —
        replicating 1T of expert weight cannot fit."""
        cfg = get_arch("kimi-k2-1t-a32b")
        out = plan(cfg, 4096, 256, "train", mesh=(1, 16, 16))
        assert out["choice"].moe_mode == "ep_all_to_all"

    def test_dp_infeasible_for_1t_params(self):
        cfg = get_arch("kimi-k2-1t-a32b")
        cm = TPUCostModel(cfg, 4096, 256, "train", MeshShape(1, 16, 16))
        dp = cm.terms(PlanChoice("dp", "full", 8, False, "ep_all_to_all"))
        assert not dp["feasible"]  # replicated 1T params >> 16 GB

    def test_remat_tradeoff_visible(self):
        """T2/N2 analogue: remat trades FLOPs for memory, monotonically."""
        cfg = get_arch("stablelm-12b")
        cm = TPUCostModel(cfg, 4096, 256, "train", MeshShape(1, 16, 16))
        none = cm.terms(PlanChoice("fsdp_tp", "none", 8, False, "none"))
        full = cm.terms(PlanChoice("fsdp_tp", "full", 8, False, "none"))
        assert full["compute_s"] > none["compute_s"]
        assert full["resident_bytes"] < none["resident_bytes"]

    def test_multi_pod_scales_compute_term(self):
        cfg = get_arch("internlm2-20b")
        one = plan(cfg, 4096, 256, "train", mesh=(1, 16, 16))
        two = plan(cfg, 4096, 256, "train", mesh=(2, 16, 16))
        assert two["terms"]["compute_s"] < one["terms"]["compute_s"]


@pytest.mark.slow  # spins up the batching server; excluded from test-fast
class TestServing:
    def test_batched_generation_deterministic(self):
        from repro.launch.serve import ServeConfig, Server
        server = Server(ServeConfig(max_new_tokens=6, max_seq=64))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, server.arch.vocab_size, 8).astype(np.int32)
                   for _ in range(3)]
        a = server.generate(prompts)
        b = server.generate(prompts)
        assert a == b
        assert all(len(o) == 6 for o in a)

    def test_batching_invariance(self):
        """A request decoded alone == decoded inside a batch (greedy)."""
        from repro.launch.serve import ServeConfig, Server
        server = Server(ServeConfig(max_new_tokens=5, max_seq=64))
        rng = np.random.default_rng(1)
        prompts = [rng.integers(0, server.arch.vocab_size, 8).astype(np.int32)
                   for _ in range(3)]
        solo = server.generate([prompts[0]])[0]
        batched = server.generate(prompts)[0]
        assert solo == batched
