"""Delta-driven + phased saturation invariants.

The delta scheduler (applicability index + per-rule dirty cursors,
``core.dag.expand``) must be a pure SCHEDULING change: the saturated
memo — and therefore the winning plan — must be identical to the
reference rescan-everything loop (``expand_exhaustive``) on every
program. The property is checked two ways:

  * exhaustively over the example-program corpus (P0/P1/P2, M0, Wilos
    A–F, SCAN, the synthetic generator);
  * over randomized synthetic programs — via hypothesis when installed,
    and via a seeded deterministic sweep that always runs in tier-1.

Also pinned here: compile-budget semantics (greedy fallback is valid and
monotone — more budget never yields a costlier plan), the union-find /
canonical-children memoization (satellite micro-perf must not change
canonicalization), per-phase rule observability, and the cross-program
MemoPool (hits, and bit-identical pooled compiles).
"""

import random

import pytest

from repro.api import CobraSession, OptimizerConfig
from repro.core import CostCatalog
from repro.core.dag import (Budget, expand, expand_exhaustive,
                            memo_fingerprint)
from repro.core.rules import RuleContext, build_memo, default_rules
from repro.core.search import run_search
from repro.programs import (WILOS_PROGRAMS, make_m0,
                            make_orders_customer_db, make_p0, make_p1,
                            make_p2, make_sales_db, make_scan,
                            make_synthetic, make_wilos_db)
from repro.relational.database import SLOW_REMOTE


@pytest.fixture(scope="module")
def oc_db():
    return make_orders_customer_db(200, 50)


@pytest.fixture(scope="module")
def sales_db():
    return make_sales_db(200)


@pytest.fixture(scope="module")
def wilos_db():
    return make_wilos_db(300, ratio=10)


def _corpus(oc_db, sales_db, wilos_db):
    progs = [(make_p0(), oc_db), (make_p1(), oc_db), (make_p2(), oc_db),
             (make_m0(), sales_db), (make_scan(), wilos_db),
             (make_synthetic(1, 8), wilos_db)]
    progs += [(mk(), wilos_db) for mk in WILOS_PROGRAMS.values()]
    return progs


def _saturate_both(program, db):
    """Saturate one program under both schedulers on fresh memos; return
    ((delta_memo, delta_stats), (exh_memo, exh_stats), roots)."""
    out = []
    roots = []
    for runner in (expand, expand_exhaustive):
        ctx = RuleContext(db=db)
        memo, root = build_memo(program, ctx)
        stats = runner(memo, default_rules(), ctx)
        out.append((memo, stats))
        roots.append(root)
    return out[0], out[1], roots


# --------------------------------------------------------------------------
# parity: delta+phased scheduling never changes the saturated memo
# --------------------------------------------------------------------------

def test_delta_matches_exhaustive_on_example_corpus(oc_db, sales_db,
                                                    wilos_db):
    for program, db in _corpus(oc_db, sales_db, wilos_db):
        (dm, ds), (xm, xs), (dr, xr) = _saturate_both(program, db)
        assert memo_fingerprint(dm, dr) == memo_fingerprint(xm, xr), \
            f"memo diverged on {program.name}"
        assert ds["alternatives_added"] == xs["alternatives_added"]
        assert not ds["budget_exhausted"] and not xs["budget_exhausted"]


def test_delta_matches_exhaustive_winning_plans(oc_db, wilos_db):
    cat = CostCatalog(SLOW_REMOTE)
    for program, db in ((make_p0(), oc_db), (make_scan(), wilos_db),
                        (make_synthetic(1, 6), wilos_db)):
        d = run_search(program, db, cat)
        x = run_search(program, db, cat, exhaustive=True)
        assert d.program.key() == x.program.key()
        assert d.est_cost == x.est_cost
        assert d.alternatives == x.alternatives


def _parity_case(wilos_db, scale, stmts):
    program = make_synthetic(scale, stmts)
    (dm, ds), (xm, xs), (dr, xr) = _saturate_both(program, wilos_db)
    assert memo_fingerprint(dm, dr) == memo_fingerprint(xm, xr), \
        f"memo diverged on synthetic(scale={scale}, stmts={stmts})"
    assert ds["alternatives_added"] == xs["alternatives_added"]


def test_delta_matches_exhaustive_seeded_random(wilos_db):
    """Tier-1 fallback for the hypothesis property: a seeded sweep of
    random synthetic-program shapes."""
    rng = random.Random(0xC0B7A)
    for _ in range(6):
        _parity_case(wilos_db, rng.randint(0, 3), rng.randint(3, 24))


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=15, deadline=None)
    @given(scale=st.integers(0, 3), stmts=st.integers(3, 24))
    def test_delta_matches_exhaustive_hypothesis(scale, stmts):
        _parity_case(make_wilos_db(300, ratio=10), scale, stmts)
except ImportError:  # optional dev dependency; the seeded sweep covers CI
    pass


# --------------------------------------------------------------------------
# compile budget: greedy fallback, monotonicity, explain surfacing
# --------------------------------------------------------------------------

def test_budget_trips_to_valid_greedy_plan(wilos_db):
    cat = CostCatalog(SLOW_REMOTE)
    program = make_synthetic(1, 30)
    full = run_search(program, wilos_db, cat)
    tight = run_search(program, wilos_db, cat, budget=Budget(node_budget=5))
    assert not full.budget_exhausted
    assert tight.budget_exhausted
    # still a plan — possibly costlier, never an error
    assert tight.program is not None
    assert tight.est_cost >= full.est_cost


def test_budget_monotonicity(wilos_db):
    """More budget never yields a costlier plan, and the unbudgeted result
    is reached once the budget stops tripping."""
    cat = CostCatalog(SLOW_REMOTE)
    program = make_synthetic(1, 10)
    full = run_search(program, wilos_db, cat)
    prev = None
    for nodes in (5, 50, 500, 10_000, None):
        budget = Budget(node_budget=nodes) if nodes is not None else None
        r = run_search(program, wilos_db, cat, budget=budget)
        if prev is not None:
            assert r.est_cost <= prev + 1e-12
        prev = r.est_cost
    assert prev == full.est_cost


def test_wall_budget_trips(wilos_db):
    r = run_search(make_synthetic(1, 10), wilos_db, CostCatalog(SLOW_REMOTE),
                   budget=Budget(wall_budget_s=1e-12))
    assert r.budget_exhausted
    assert r.program is not None


def test_budget_surfaces_in_report_and_explain(wilos_db):
    sess = CobraSession(wilos_db, CostCatalog(SLOW_REMOTE),
                        config=OptimizerConfig(node_budget=5))
    exe = sess.compile(make_synthetic(1, 10))
    assert exe.report.budget_exhausted
    assert "BUDGET EXHAUSTED" in exe.report.describe()
    assert "EXHAUSTED" in exe.explain()
    run = exe.run()
    assert run.outputs  # the greedy plan executes

    unbudgeted = CobraSession(wilos_db, CostCatalog(SLOW_REMOTE))
    exe2 = unbudgeted.compile(make_synthetic(1, 10))
    assert not exe2.report.budget_exhausted
    assert "EXHAUSTED" not in exe2.explain()


# --------------------------------------------------------------------------
# memo micro-perf: canonicalization must survive memoization/compression
# --------------------------------------------------------------------------

def test_canonical_children_match_naive_on_saturated_memos(wilos_db):
    (memo, _stats), _, _ = _saturate_both(make_scan(), wilos_db)
    for a, node in memo._ands.items():
        naive = tuple(memo.find(c) for c in node.children)
        assert memo.canonical_children(a) == naive


def test_canonical_children_cache_invalidated_by_union():
    """The memoized canonical_children must never serve a pre-merge
    answer (no example program merges groups today, so this exercises
    ``_union`` directly)."""
    from repro.core.dag import AndNode, Memo
    memo = Memo()
    ga, _ = memo.insert(AndNode("leaf", (), ("x",)))
    gb, _ = memo.insert(AndNode("leaf", (), ("y",)))
    _, pid = memo.insert(AndNode("pair", (ga, gb), ("p",)))
    assert memo.canonical_children(pid) == (ga, gb)   # now memoized
    memo._union(ga, gb)
    assert memo.merges == 1
    root = memo.find(ga)
    assert memo.find(gb) == root
    assert memo.canonical_children(pid) == (root, root)
    naive = tuple(memo.find(c) for c in memo.node(pid).children)
    assert memo.canonical_children(pid) == naive


def test_find_is_idempotent_and_root_stable(wilos_db):
    (memo, _stats), _, _ = _saturate_both(make_scan(), wilos_db)
    for g in list(memo._parent):
        r = memo.find(g)
        assert memo.find(r) == r            # roots are fixpoints
        assert memo.find(g) == r            # compression kept the answer
    # stats() root counting agrees with find()-derived roots
    roots = {memo.find(g) for g in memo._parent}
    assert memo.stats()["groups"] == len(roots)


# --------------------------------------------------------------------------
# per-phase rule observability
# --------------------------------------------------------------------------

def test_rule_stats_per_phase(oc_db):
    cat = CostCatalog(SLOW_REMOTE)
    r = run_search(make_p0(), oc_db, cat)
    assert "normalize" in r.rule_stats and "explore" in r.rule_stats
    tofir = r.rule_stats["normalize"]["toFIR"]
    assert tofir["fired"] >= 1
    assert tofir["matched"] >= tofir["fired"]
    explore = r.rule_stats["explore"]
    assert any(st["matched"] > 0 for st in explore.values())
    # missed = matched - fired, per rule
    for phase in r.rule_stats.values():
        for st in phase.values():
            assert st["missed"] == st["matched"] - st["fired"]


def test_rule_stats_render_in_explain(oc_db):
    sess = CobraSession(oc_db, CostCatalog(SLOW_REMOTE))
    exe = sess.compile(make_p0())
    text = exe.explain()
    assert "saturation phase normalize" in text
    assert "saturation phase explore" in text
    assert "toFIR fired" in text


# --------------------------------------------------------------------------
# cross-program memo pool
# --------------------------------------------------------------------------

def test_memo_pool_cross_program_hits(wilos_db):
    import dataclasses
    sess = CobraSession(wilos_db, CostCatalog(SLOW_REMOTE))
    sess.compile(make_synthetic(1, 6))
    assert sess.telemetry["memo_pool_hits"] == 0
    assert sess.telemetry["memo_pool_entries"] > 0
    # scale-2 shares the scale-1 loops verbatim -> replayed from the pool
    sess.compile(dataclasses.replace(make_synthetic(2, 6), name="SYN_B"))
    assert sess.telemetry["memo_pool_hits"] > 0


def test_memo_pool_replayed_memo_is_bit_identical(wilos_db):
    """The replayed memo must have the same fingerprint as a cold
    compile's — the pool shares the saturated STRUCTURE exactly."""
    import dataclasses
    rules = default_rules()
    from repro.core.memopool import MemoPool
    pool = MemoPool()
    ctx1 = RuleContext(db=wilos_db)
    m1, _ = build_memo(make_synthetic(1, 6), ctx1)
    expand(m1, rules, ctx1)
    pool.harvest(m1, ctx1, rules, set())

    prog_b = dataclasses.replace(make_synthetic(2, 6), name="SYN_B")
    ctx2 = RuleContext(db=wilos_db)
    warm_memo, warm_root = build_memo(prog_b, ctx2)
    _, prefired = pool.seed(warm_memo, ctx2, rules)
    assert pool.hits > 0
    expand(warm_memo, rules, ctx2, prefired=prefired)

    ctx3 = RuleContext(db=wilos_db)
    cold_memo, cold_root = build_memo(prog_b, ctx3)
    expand(cold_memo, rules, ctx3)
    assert (memo_fingerprint(warm_memo, warm_root)
            == memo_fingerprint(cold_memo, cold_root))


def test_memo_pool_compile_matches_cold(wilos_db):
    """A pooled compile picks the same plan at the same cost with the
    same outputs as a pool-free cold compile. Rule-hit ATTEMPT counters
    may read lower (duplicate derivations are not replayed), but never
    higher and never for a rule the cold compile didn't fire."""
    import dataclasses
    prog_b = dataclasses.replace(make_synthetic(2, 6), name="SYN_B")

    pooled = CobraSession(wilos_db, CostCatalog(SLOW_REMOTE))
    pooled.compile(make_synthetic(1, 6))        # seeds the pool
    warm = pooled.compile(prog_b)
    assert pooled.telemetry["memo_pool_hits"] > 0

    cold_sess = CobraSession(wilos_db, CostCatalog(SLOW_REMOTE))
    cold = cold_sess.compile(prog_b)

    assert repr(warm.program.body) == repr(cold.program.body)
    assert warm.est_cost_s == cold.est_cost_s
    assert warm.result.alternatives <= cold.result.alternatives
    for rule, n in warm.result.rule_hits.items():
        assert n <= cold.result.rule_hits.get(rule, 0)
    assert warm.run().outputs == cold.run().outputs


def test_memo_pool_not_harvested_when_budget_trips(wilos_db):
    sess = CobraSession(wilos_db, CostCatalog(SLOW_REMOTE),
                        config=OptimizerConfig(node_budget=5))
    exe = sess.compile(make_synthetic(1, 6))
    assert exe.report.budget_exhausted
    # a partial memo must never be replayed into later compiles
    assert sess.telemetry["memo_pool_entries"] == 0
