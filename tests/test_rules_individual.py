"""Per-rule semantic-equivalence tests (T1–T5, T4j, N1, N1a, N2) — each rule
exercised on a minimal program whose memo must contain the expected
alternative, and every alternative must execute to the same state."""

import numpy as np
import pytest

from repro.core import CostCatalog, Interpreter
from repro.core.cost import CostModel
from repro.core.dag import expand
from repro.core.regions import (Assign, BasicBlock, CollectionAdd, CondRegion,
                                IBin, ICall, IConst, IEmptyList, IField,
                                ILoadAll, IQuery, IVar, LoopRegion, Program,
                                seq)
from repro.core.rules import RuleContext, build_memo, default_rules
from repro.core.search import Searcher, hoist_prefetches, plan_to_region
from repro.relational import (Cmp, Col, DatabaseServer, Field, Param, Scan,
                              Schema, Select, Table)
from repro.relational.database import ClientEnv, FAST_LOCAL


@pytest.fixture
def db():
    rng = np.random.default_rng(4)
    n, nk = 60, 9
    items = Table.from_columns(
        "items", Schema.of(Field("i_id", "int64", 8), Field("i_k", "int64", 8),
                           Field("i_v", "float32", 4)),
        i_id=np.arange(n), i_k=rng.integers(0, nk, n),
        i_v=rng.uniform(0, 10, n).astype(np.float32))
    keys = Table.from_columns(
        "keys", Schema.of(Field("k_id", "int64", 8), Field("k_r", "int32", 4)),
        k_id=np.arange(nk), k_r=rng.integers(0, 5, nk))
    return DatabaseServer({"items": items, "keys": keys})


def all_plans_equivalent(prog, db, init=None, expect_ops=()):
    """Expand the memo; every top-K root plan must execute identically.
    Returns the set of AND ops seen across plans."""
    env0 = ClientEnv(db, FAST_LOCAL)
    o0 = Interpreter(env0, "exact").run(prog, init)
    ctx = RuleContext(db=db)
    memo, root = build_memo(prog, ctx)
    expand(memo, default_rules(), ctx)
    searcher = Searcher(memo, CostModel(db, CostCatalog(FAST_LOCAL)), ctx)
    plans = searcher.group_plans(root)
    assert plans, "no plans"
    seen_ops = set()

    def collect(p):
        seen_ops.add(p.op)
        for c in p.children:
            collect(c)

    for plan in plans:
        collect(plan)
        region = hoist_prefetches(plan_to_region(plan))
        env1 = ClientEnv(db, FAST_LOCAL)
        o1 = Interpreter(env1, "exact").run(Program("alt", region,
                                                    prog.outputs), init)
        for k in o0:
            a, b = o0[k], o1[k]
            if isinstance(a, list):
                np.testing.assert_allclose(np.sort(np.asarray(a, np.float64)),
                                           np.sort(np.asarray(b, np.float64)),
                                           rtol=1e-4, atol=1e-4)
            else:
                assert abs(float(a) - float(b)) < 1e-3 * max(1, abs(float(a)))
    for op in expect_ops:
        assert op in seen_ops, (op, seen_ops)
    return seen_ops


def test_T1_fold_removal(db):
    # result.add(t) over a plain scan with empty init → query-assign
    prog = Program("t1", seq(
        Assign("out", IEmptyList()),
        LoopRegion("t", ILoadAll("items"),
                   BasicBlock(CollectionAdd("out", IVar("t"))))), ("out",))
    ctx = RuleContext(db=db)
    memo, root = build_memo(prog, ctx)
    expand(memo, default_rules(), ctx)
    ops = {memo.node(a).op for a in memo._ands}
    assert "slot-query-rows" in ops  # T1 fired


def test_T5_sum_extraction(db):
    prog = Program("t5", seq(
        Assign("s", IConst(0.0)),
        LoopRegion("t", ILoadAll("items"),
                   BasicBlock(Assign("s", IBin("+", IVar("s"),
                                               IField(IVar("t"), "i_v")))))),
        ("s",))
    ops = all_plans_equivalent(prog, db, expect_ops=())
    ctx = RuleContext(db=db)
    memo, _ = build_memo(prog, ctx)
    expand(memo, default_rules(), ctx)
    assert any(memo.node(a).op == "slot-query" for a in memo._ands)


def test_T5_guarded_becomes_sigma_agg(db):
    # guarded count → γ count over σ (T2 ∘ T5)
    prog = Program("t5g", seq(
        Assign("n", IConst(0)),
        LoopRegion("t", ILoadAll("items"),
                   CondRegion(IBin("<", IField(IVar("t"), "i_v"), IConst(5.0)),
                              BasicBlock(Assign("n", IBin("+", IVar("n"),
                                                          IConst(1))))))),
        ("n",))
    all_plans_equivalent(prog, db)


def test_T2_T4_nested_join(db):
    inner = LoopRegion(
        "y", ILoadAll("keys"),
        CondRegion(IBin("==", IField(IVar("y"), "k_id"),
                        IField(IVar("x"), "i_k")),
                   BasicBlock(CollectionAdd(
                       "out", ICall("combine", (IField(IVar("x"), "i_v"),
                                                IField(IVar("y"), "k_r")))))))
    prog = Program("t4", seq(Assign("out", IEmptyList()),
                             LoopRegion("x", ILoadAll("items"), inner)),
                   ("out",))
    ctx = RuleContext(db=db)
    memo, _ = build_memo(prog, ctx)
    expand(memo, default_rules(), ctx)
    ops = {memo.node(a).op for a in memo._ands}
    assert "slot-query-rows" in ops  # T2c ∘ T4 produced the join
    all_plans_equivalent(prog, db)


def test_N1_point_lookup_prefetch(db):
    from repro.core.regions import INav
    body = seq(
        Assign("r", INav(IVar("t"), "i_k", "keys", "k_id")),
        CollectionAdd("out", IField(IVar("r"), "k_r")))
    prog = Program("n1", seq(Assign("out", IEmptyList()),
                             LoopRegion("t", ILoadAll("items"), body)),
                   ("out",))
    ctx = RuleContext(db=db)
    memo, _ = build_memo(prog, ctx)
    expand(memo, default_rules(), ctx)
    # N1 produced a prefetch-bearing alternative AND T4j produced a join
    payloads = [memo.node(a).payload for a in memo._ands
                if memo.node(a).op == "slot-project"]
    assert any("prefetch" in repr(p) for p in payloads)      # N1
    assert any("JOIN" in repr(p).upper() for p in payloads)  # T4j
    all_plans_equivalent(prog, db)


def test_N1a_correlated_query_prefetch(db):
    inner_q = IQuery(Select(Cmp("==", Col("i_k"), Param("k")), Scan("items")),
                     (("k", IField(IVar("x"), "k_id")),))
    inner = LoopRegion("y", inner_q,
                       BasicBlock(Assign("s", IBin("+", IVar("s"),
                                                   IField(IVar("y"), "i_v")))))
    prog = Program("n1a", seq(Assign("s", IConst(0.0)),
                              LoopRegion("x", ILoadAll("keys"),
                                         seq(inner))), ("s",))
    all_plans_equivalent(prog, db)
    ctx = RuleContext(db=db)
    memo, _ = build_memo(prog, ctx)
    expand(memo, default_rules(), ctx)
    payloads = [repr(memo.node(a).payload) for a in memo._ands
                if memo.node(a).op == "slot-project"]
    assert any("lookupAll" in p for p in payloads)  # N1a fired


def test_N2_reverse_of_T2(db):
    # source already σ-filtered: N2 pulls the filter out; T2 pushes it back;
    # dedup must terminate and all plans agree
    q = Select(Cmp(">", Col("i_v"), Col("i_v")), Scan("items"))  # empty-ish
    q = Select(Cmp("==", Col("i_k"), Col("i_k")), Scan("items"))  # all rows
    prog = Program("n2", seq(
        Assign("s", IConst(0.0)),
        LoopRegion("t", IQuery(Select(Cmp("<", Col("i_v"), Col("i_v")),
                                      Scan("items")) if False else
                               Select(Cmp("<", Col("i_v"), Col("i_k")),
                                      Scan("items"))),
                   BasicBlock(Assign("s", IBin("+", IVar("s"),
                                               IField(IVar("t"), "i_v")))))),
        ("s",))
    ctx = RuleContext(db=db)
    memo, root = build_memo(prog, ctx)
    stats = expand(memo, default_rules(), ctx)
    assert stats["rounds"] < 64           # cyclic T2/N2 terminated
    all_plans_equivalent(prog, db)


def test_T3_scalar_push(db):
    prog = Program("t3", seq(
        Assign("out", IEmptyList()),
        LoopRegion("t", ILoadAll("items"),
                   BasicBlock(CollectionAdd("out", ICall(
                       "scale", (IField(IVar("t"), "i_v"),)))))), ("out",))
    ctx = RuleContext(db=db)
    memo, _ = build_memo(prog, ctx)
    expand(memo, default_rules(), ctx)
    payloads = [repr(memo.node(a).payload) for a in memo._ands
                if memo.node(a).op == "slot-project"]
    assert any("h_val" in p for p in payloads)  # T3 pushed scale() into π
    all_plans_equivalent(prog, db)
